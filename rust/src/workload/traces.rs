//! Workload traces: persist and replay multi-campaign arrival streams
//! and recorded load-generator request tapes.
//!
//! Grid/cloud BoT workloads arrive in bursts over time (Iosup & Epema,
//! the paper's ref. [1]).  Two trace kinds live here:
//!
//! * [`Trace`] — a sequence of campaigns (arrival time + full system
//!   description + budget) that the replay driver feeds to the planner
//!   one by one (`botsched trace gen/replay`).
//! * [`LoadTrace`] — a recorded open-loop traffic tape from
//!   [`crate::loadgen`]: every request the generator sent, with its
//!   scheduled offset and owning client, so a run replays bit-identically
//!   against a live coordinator.
//!
//! Both serialise to JSON under an explicit [`TRACE_VERSION`] and load
//! through **strict schema validation**: unknown fields and mistyped
//! values are rejected with errors that name the offending field, so a
//! malformed tape fails loudly instead of mis-generating traffic.

use anyhow::{anyhow, Context, Result};

use crate::config;
use crate::model::System;
use crate::util::{Json, Rng};
use crate::workload::{SizeDistribution, WorkloadGenerator, WorkloadSpec};

/// The trace schema version stamped into every saved trace.  Loaders
/// reject any other value — bump it when the schema changes shape.
pub const TRACE_VERSION: u64 = 1;

/// Strict object check: `j` must be an object whose every key is in
/// `allowed`.  Errors name the unknown field and the allowed set.
fn check_fields(ctx: &str, j: &Json, allowed: &[&str]) -> Result<()> {
    let Json::Obj(m) = j else {
        return Err(anyhow!("{ctx}: expected a JSON object, got {j}"));
    };
    for k in m.keys() {
        if !allowed.contains(&k.as_str()) {
            return Err(anyhow!("{ctx}: unknown field {k:?} (allowed: {allowed:?})"));
        }
    }
    Ok(())
}

/// A required numeric field, with a field-naming error on absence or a
/// wrong type.
fn need_f64(ctx: &str, j: &Json, key: &str) -> Result<f64> {
    match j.get(key) {
        None => Err(anyhow!("{ctx}: missing field {key:?}")),
        Some(v) => v.as_f64().ok_or_else(|| anyhow!("{ctx}: field {key:?} must be a number, got {v}")),
    }
}

/// A required non-negative integer field (same error discipline).
fn need_u64(ctx: &str, j: &Json, key: &str) -> Result<u64> {
    match j.get(key) {
        None => Err(anyhow!("{ctx}: missing field {key:?}")),
        Some(v) => v
            .as_u64()
            .ok_or_else(|| anyhow!("{ctx}: field {key:?} must be a non-negative integer, got {v}")),
    }
}

/// A required string field (same error discipline).
fn need_str<'j>(ctx: &str, j: &'j Json, key: &str) -> Result<&'j str> {
    match j.get(key) {
        None => Err(anyhow!("{ctx}: missing field {key:?}")),
        Some(v) => v.as_str().ok_or_else(|| anyhow!("{ctx}: field {key:?} must be a string, got {v}")),
    }
}

/// Check an optional `"version"` stamp (campaign traces predate the
/// stamp, so absence is accepted as version 1) or a required one
/// (load tapes always carry it).
fn check_version(ctx: &str, j: &Json, required: bool) -> Result<()> {
    match j.get("version") {
        None if required => Err(anyhow!("{ctx}: missing field \"version\"")),
        None => Ok(()),
        Some(v) => {
            let n = v
                .as_u64()
                .ok_or_else(|| anyhow!("{ctx}: field \"version\" must be an integer, got {v}"))?;
            if n != TRACE_VERSION {
                return Err(anyhow!(
                    "{ctx}: unsupported trace version {n} (this build reads version {TRACE_VERSION})"
                ));
            }
            Ok(())
        }
    }
}

/// One campaign in a trace.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival time (seconds from trace start).
    pub at: f64,
    pub budget: f64,
    pub system: System,
}

/// A replayable stream of campaigns.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    /// Synthesize a bursty arrival trace: `n_campaigns` Poisson arrivals
    /// (exponential gaps with the given mean), each with a freshly
    /// generated system of varying shape and a budget drawn around that
    /// system's feasibility floor.
    pub fn synthetic(seed: u64, n_campaigns: usize, mean_gap: f64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut gen = WorkloadGenerator::new(seed.wrapping_mul(31).wrapping_add(7));
        let mut t = 0.0;
        let mut entries = Vec::with_capacity(n_campaigns);
        for i in 0..n_campaigns {
            t += rng.exponential(1.0 / mean_gap.max(1e-9));
            let spec = WorkloadSpec {
                n_apps: 1 + (rng.below(4) as usize),
                n_types: 2 + (rng.below(4) as usize),
                tasks_per_app: 30 + (rng.below(120) as usize),
                sizes: if i % 2 == 0 {
                    SizeDistribution::EquallySpaced { lo: 1, hi: 5 }
                } else {
                    SizeDistribution::LogNormal { mu: 0.7, sigma: 0.5 }
                },
                overhead: rng.uniform(0.0, 120.0),
                ..Default::default()
            };
            let system = gen.system(&spec);
            let floor = WorkloadGenerator::feasible_budget(&system, 1.0);
            let budget = (floor * rng.uniform(1.1, 2.2)).ceil();
            entries.push(TraceEntry { at: t, budget, system });
        }
        Trace { entries }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(TRACE_VERSION as f64)),
            (
                "campaigns",
                Json::arr(self.entries.iter().map(|e| {
                    Json::obj(vec![
                        ("at", Json::num(e.at)),
                        ("budget", Json::num(e.budget)),
                        ("system", config::system_to_json(&e.system)),
                    ])
                })),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        check_fields("trace", j, &["version", "campaigns"])?;
        check_version("trace", j, false)?;
        let arr = j
            .get("campaigns")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace: missing campaigns[]"))?;
        let mut entries = Vec::with_capacity(arr.len());
        let mut last_at = f64::NEG_INFINITY;
        for (i, e) in arr.iter().enumerate() {
            let ctx = format!("trace campaign {i}");
            check_fields(&ctx, e, &["at", "budget", "system"])?;
            let at = need_f64(&ctx, e, "at")?;
            if at < last_at {
                return Err(anyhow!("{ctx}: arrivals not sorted"));
            }
            last_at = at;
            let budget = need_f64(&ctx, e, "budget")?;
            let system = config::system_from_json(
                e.get("system").ok_or_else(|| anyhow!("{ctx}: missing field \"system\""))?,
            )
            .with_context(|| ctx.clone())?;
            entries.push(TraceEntry { at, budget, system });
        }
        Ok(Trace { entries })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Trace::from_json(&Json::parse(&text)?)
    }
}

// ---------------------------------------------------------------------------
// Load-generator tapes.

/// One recorded request in a [`LoadTrace`]: when it was scheduled
/// (microseconds from run start), which client connection sends it, and
/// the full encoded wire request.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadEntry {
    pub at_us: u64,
    pub client: u32,
    /// The encoded [`crate::coordinator::api::Request`] object (no
    /// `"v"` stamp — the client adds it at send time).
    pub request: Json,
}

/// A recorded open-loop traffic tape (see [`crate::loadgen`]): the
/// exact request sequence of one generated run, replayable against any
/// live coordinator.  The generator's knobs are echoed so reports can
/// state the offered load the tape encodes.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadTrace {
    pub seed: u64,
    /// Offered arrival rate (requests/second) the tape was generated at.
    pub offered_rate: f64,
    pub duration_s: f64,
    /// Client connections the entries are partitioned across.
    pub clients: u32,
    /// The arrival-process grammar string (e.g. `"poisson"`,
    /// `"bursty:on=2,off=8"`) — informative echo, not re-sampled.
    pub arrival: String,
    pub entries: Vec<LoadEntry>,
}

impl LoadTrace {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(TRACE_VERSION as f64)),
            ("kind", Json::str("loadgen")),
            ("seed", Json::num(self.seed as f64)),
            ("offered_rate", Json::num(self.offered_rate)),
            ("duration_s", Json::num(self.duration_s)),
            ("clients", Json::num(f64::from(self.clients))),
            ("arrival", Json::str(&self.arrival)),
            (
                "requests",
                Json::arr(self.entries.iter().map(|e| {
                    Json::obj(vec![
                        ("at_us", Json::num(e.at_us as f64)),
                        ("client", Json::num(f64::from(e.client))),
                        ("request", e.request.clone()),
                    ])
                })),
            ),
        ])
    }

    /// Strict, schema-checked load.  Every entry's `request` object is
    /// additionally run through the typed wire decoder
    /// ([`crate::coordinator::api::Request::decode`]), so a tape that
    /// parses as JSON but encodes an invalid request still fails here —
    /// with the entry index — rather than at send time mid-run.
    pub fn from_json(j: &Json) -> Result<LoadTrace> {
        check_fields(
            "load trace",
            j,
            &["version", "kind", "seed", "offered_rate", "duration_s", "clients", "arrival", "requests"],
        )?;
        check_version("load trace", j, true)?;
        let kind = need_str("load trace", j, "kind")?;
        if kind != "loadgen" {
            return Err(anyhow!(
                "load trace: field \"kind\" must be \"loadgen\", got {kind:?} \
                 (campaign traces replay via `botsched trace replay`)"
            ));
        }
        let seed = need_u64("load trace", j, "seed")?;
        let offered_rate = need_f64("load trace", j, "offered_rate")?;
        let duration_s = need_f64("load trace", j, "duration_s")?;
        let clients = need_u64("load trace", j, "clients")?;
        if clients == 0 || clients > u64::from(u32::MAX) {
            return Err(anyhow!("load trace: field \"clients\" out of range, got {clients}"));
        }
        let arrival = need_str("load trace", j, "arrival")?.to_string();
        let arr = j
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("load trace: field \"requests\" must be an array"))?;
        let mut entries = Vec::with_capacity(arr.len());
        let mut last_at = 0u64;
        for (i, e) in arr.iter().enumerate() {
            let ctx = format!("load trace request {i}");
            check_fields(&ctx, e, &["at_us", "client", "request"])?;
            let at_us = need_u64(&ctx, e, "at_us")?;
            if at_us < last_at {
                return Err(anyhow!("{ctx}: arrivals not sorted (at_us {at_us} after {last_at})"));
            }
            last_at = at_us;
            let client = need_u64(&ctx, e, "client")?;
            if client >= clients {
                return Err(anyhow!(
                    "{ctx}: field \"client\" is {client} but the tape declares {clients} clients"
                ));
            }
            let request = e
                .get("request")
                .ok_or_else(|| anyhow!("{ctx}: missing field \"request\""))?
                .clone();
            crate::coordinator::api::Request::decode(&request)
                .map_err(|err| anyhow!("{ctx}: invalid request: {}", err.message))?;
            entries.push(LoadEntry { at_us, client: client as u32, request });
        }
        Ok(LoadTrace { seed, offered_rate, duration_s, clients: clients as u32, arrival, entries })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<LoadTrace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        LoadTrace::from_json(&Json::parse(&text)?)
    }
}

/// Replay outcome for one campaign.
#[derive(Debug, Clone)]
pub struct ReplayRow {
    pub at: f64,
    pub budget: f64,
    pub makespan: f64,
    pub cost: f64,
    pub feasible: bool,
    /// Completion wall-clock (arrival + planning-ignored makespan).
    pub finish_at: f64,
}

/// Replay a trace through the planner (campaigns are independent — each
/// gets its own fleet, as in the paper's model).
pub fn replay(trace: &Trace) -> Vec<ReplayRow> {
    trace
        .entries
        .iter()
        .map(|e| {
            let r = crate::scheduler::Planner::new(&e.system).find(e.budget);
            ReplayRow {
                at: e.at,
                budget: e.budget,
                makespan: r.score.makespan,
                cost: r.score.cost,
                feasible: r.feasible,
                finish_at: e.at + r.score.makespan,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_trace_is_sorted_and_deterministic() {
        let t1 = Trace::synthetic(5, 10, 600.0);
        let t2 = Trace::synthetic(5, 10, 600.0);
        assert_eq!(t1.entries.len(), 10);
        for w in t1.entries.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        for (a, b) in t1.entries.iter().zip(&t2.entries) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.budget, b.budget);
            assert_eq!(a.system.tasks().len(), b.system.tasks().len());
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = Trace::synthetic(7, 4, 300.0);
        let j = t.to_json();
        assert_eq!(j.get("version").unwrap().as_u64(), Some(TRACE_VERSION));
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back.entries.len(), 4);
        for (a, b) in t.entries.iter().zip(&back.entries) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.budget, b.budget);
            assert_eq!(a.system.tasks().len(), b.system.tasks().len());
            assert_eq!(a.system.n_types(), b.system.n_types());
        }
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace::synthetic(9, 3, 100.0);
        let dir = std::env::temp_dir().join("botsched_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.entries.len(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unsorted_trace_rejected() {
        let j = Json::parse(
            r#"{"campaigns":[
                {"at": 10, "budget": 5, "system": {"apps":[{"task_sizes":[1]}],
                  "instance_types":[{"cost_per_hour":5,"perf":[10]}]}},
                {"at": 5, "budget": 5, "system": {"apps":[{"task_sizes":[1]}],
                  "instance_types":[{"cost_per_hour":5,"perf":[10]}]}}
            ]}"#,
        )
        .unwrap();
        assert!(Trace::from_json(&j).is_err());
    }

    #[test]
    fn campaign_trace_schema_violations_name_the_field() {
        // A version-less tape stays loadable (campaign traces predate
        // the stamp), but a wrong version is rejected by number.
        let versionless = r#"{"campaigns":[]}"#;
        assert!(Trace::from_json(&Json::parse(versionless).unwrap()).is_ok());
        let wrong = r#"{"version":99,"campaigns":[]}"#;
        let err = Trace::from_json(&Json::parse(wrong).unwrap()).unwrap_err().to_string();
        assert!(err.contains("version 99"), "{err}");

        // Unknown top-level and entry-level fields are named.
        let extra_top = r#"{"campaigns":[],"frobnicate":1}"#;
        let err = Trace::from_json(&Json::parse(extra_top).unwrap()).unwrap_err().to_string();
        assert!(err.contains("frobnicate"), "{err}");
        let extra_entry = r#"{"campaigns":[
            {"at":1,"budget":5,"surprise":true,"system":{"apps":[{"task_sizes":[1]}],
              "instance_types":[{"cost_per_hour":5,"perf":[10]}]}}]}"#;
        let err = Trace::from_json(&Json::parse(extra_entry).unwrap()).unwrap_err().to_string();
        assert!(err.contains("surprise") && err.contains("campaign 0"), "{err}");

        // A mistyped value names its field, not just the entry.
        let bad_type = r#"{"campaigns":[
            {"at":"soon","budget":5,"system":{"apps":[{"task_sizes":[1]}],
              "instance_types":[{"cost_per_hour":5,"perf":[10]}]}}]}"#;
        let err = Trace::from_json(&Json::parse(bad_type).unwrap()).unwrap_err().to_string();
        assert!(err.contains("\"at\"") && err.contains("number"), "{err}");
    }

    fn tiny_load_trace() -> LoadTrace {
        LoadTrace {
            seed: 7,
            offered_rate: 50.0,
            duration_s: 1.0,
            clients: 2,
            arrival: "poisson".into(),
            entries: vec![
                LoadEntry {
                    at_us: 1_000,
                    client: 0,
                    request: Json::parse(r#"{"op":"plan","budget":80,"scenario":"uniform-small"}"#)
                        .unwrap(),
                },
                LoadEntry {
                    at_us: 5_000,
                    client: 1,
                    request: Json::parse(r#"{"op":"ping"}"#).unwrap(),
                },
            ],
        }
    }

    #[test]
    fn load_trace_roundtrips_bit_identically() {
        let t = tiny_load_trace();
        let j = t.to_json();
        let back = LoadTrace::from_json(&j).unwrap();
        assert_eq!(back, t);
        // Serialisation is canonical (sorted object keys), so the
        // round-tripped JSON text is byte-identical too.
        assert_eq!(back.to_json().to_string(), j.to_string());

        let dir = std::env::temp_dir().join("botsched_loadtrace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tape.json");
        t.save(&path).unwrap();
        assert_eq!(LoadTrace::load(&path).unwrap(), t);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_trace_requires_its_version_and_kind() {
        let t = tiny_load_trace();
        let Json::Obj(mut m) = t.to_json() else { unreachable!() };
        m.remove("version");
        let err = LoadTrace::from_json(&Json::Obj(m.clone())).unwrap_err().to_string();
        assert!(err.contains("\"version\""), "{err}");
        m.insert("version".into(), Json::num(2.0));
        let err = LoadTrace::from_json(&Json::Obj(m.clone())).unwrap_err().to_string();
        assert!(err.contains("version 2"), "{err}");
        m.insert("version".into(), Json::num(TRACE_VERSION as f64));
        m.insert("kind".into(), Json::str("campaign"));
        let err = LoadTrace::from_json(&Json::Obj(m)).unwrap_err().to_string();
        assert!(err.contains("\"kind\""), "{err}");
    }

    #[test]
    fn load_trace_rejects_malformed_tapes_loudly() {
        let t = tiny_load_trace();
        // Unknown top-level field.
        let Json::Obj(mut m) = t.to_json() else { unreachable!() };
        m.insert("surprise".into(), Json::num(1.0));
        let err = LoadTrace::from_json(&Json::Obj(m)).unwrap_err().to_string();
        assert!(err.contains("surprise"), "{err}");

        // Mistyped at_us names the field and the entry.
        let mut bad = t.clone();
        bad.entries[0].at_us = 9_000_000; // out of order vs entry 1
        let err = LoadTrace::from_json(&bad.to_json()).unwrap_err().to_string();
        assert!(err.contains("not sorted"), "{err}");

        // A client index past the declared fan-out is rejected.
        let mut bad = t.clone();
        bad.entries[1].client = 9;
        let err = LoadTrace::from_json(&bad.to_json()).unwrap_err().to_string();
        assert!(err.contains("\"client\"") && err.contains('9'), "{err}");

        // A request that is not a valid wire op fails with the entry index.
        let mut bad = t.clone();
        bad.entries[1].request = Json::parse(r#"{"op":"frobnicate"}"#).unwrap();
        let err = LoadTrace::from_json(&bad.to_json()).unwrap_err().to_string();
        assert!(err.contains("request 1") && err.contains("invalid request"), "{err}");

        // A structurally bad request body (plan without budget) too.
        let mut bad = t;
        bad.entries[0].request = Json::parse(r#"{"op":"plan"}"#).unwrap();
        let err = LoadTrace::from_json(&bad.to_json()).unwrap_err().to_string();
        assert!(err.contains("request 0") && err.contains("budget"), "{err}");
    }

    #[test]
    fn replay_produces_sane_rows() {
        let t = Trace::synthetic(11, 5, 200.0);
        let rows = replay(&t);
        assert_eq!(rows.len(), 5);
        for (r, e) in rows.iter().zip(&t.entries) {
            assert_eq!(r.at, e.at);
            assert!(r.finish_at >= r.at);
            assert!(r.makespan > 0.0);
            if r.feasible {
                assert!(r.cost <= r.budget + 1e-9);
            }
        }
        // Generated budgets are >= 1.1x the floor, so most should be feasible.
        assert!(rows.iter().filter(|r| r.feasible).count() >= 3);
    }
}
