//! Workload traces: persist and replay multi-campaign arrival streams.
//!
//! Grid/cloud BoT workloads arrive in bursts over time (Iosup & Epema,
//! the paper's ref. [1]).  A [`Trace`] is a sequence of campaigns — each
//! an arrival time plus a full system description and budget — that the
//! replay driver feeds to the planner/coordinator one by one.  Traces
//! serialise to JSON (the same schema the `config` module uses per
//! system) so benchmark inputs can be versioned and shared.

use anyhow::{anyhow, Context, Result};

use crate::config;
use crate::model::System;
use crate::util::{Json, Rng};
use crate::workload::{SizeDistribution, WorkloadGenerator, WorkloadSpec};

/// One campaign in a trace.
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Arrival time (seconds from trace start).
    pub at: f64,
    pub budget: f64,
    pub system: System,
}

/// A replayable stream of campaigns.
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub entries: Vec<TraceEntry>,
}

impl Trace {
    /// Synthesize a bursty arrival trace: `n_campaigns` Poisson arrivals
    /// (exponential gaps with the given mean), each with a freshly
    /// generated system of varying shape and a budget drawn around that
    /// system's feasibility floor.
    pub fn synthetic(seed: u64, n_campaigns: usize, mean_gap: f64) -> Trace {
        let mut rng = Rng::new(seed);
        let mut gen = WorkloadGenerator::new(seed.wrapping_mul(31).wrapping_add(7));
        let mut t = 0.0;
        let mut entries = Vec::with_capacity(n_campaigns);
        for i in 0..n_campaigns {
            t += rng.exponential(1.0 / mean_gap.max(1e-9));
            let spec = WorkloadSpec {
                n_apps: 1 + (rng.below(4) as usize),
                n_types: 2 + (rng.below(4) as usize),
                tasks_per_app: 30 + (rng.below(120) as usize),
                sizes: if i % 2 == 0 {
                    SizeDistribution::EquallySpaced { lo: 1, hi: 5 }
                } else {
                    SizeDistribution::LogNormal { mu: 0.7, sigma: 0.5 }
                },
                overhead: rng.uniform(0.0, 120.0),
                ..Default::default()
            };
            let system = gen.system(&spec);
            let floor = WorkloadGenerator::feasible_budget(&system, 1.0);
            let budget = (floor * rng.uniform(1.1, 2.2)).ceil();
            entries.push(TraceEntry { at: t, budget, system });
        }
        Trace { entries }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![(
            "campaigns",
            Json::arr(self.entries.iter().map(|e| {
                Json::obj(vec![
                    ("at", Json::num(e.at)),
                    ("budget", Json::num(e.budget)),
                    ("system", config::system_to_json(&e.system)),
                ])
            })),
        )])
    }

    pub fn from_json(j: &Json) -> Result<Trace> {
        let arr = j
            .get("campaigns")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("trace: missing campaigns[]"))?;
        let mut entries = Vec::with_capacity(arr.len());
        let mut last_at = f64::NEG_INFINITY;
        for (i, e) in arr.iter().enumerate() {
            let at = e
                .get("at")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace campaign {i}: missing at"))?;
            if at < last_at {
                return Err(anyhow!("trace campaign {i}: arrivals not sorted"));
            }
            last_at = at;
            let budget = e
                .get("budget")
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow!("trace campaign {i}: missing budget"))?;
            let system = config::system_from_json(
                e.get("system").ok_or_else(|| anyhow!("trace campaign {i}: missing system"))?,
            )
            .with_context(|| format!("trace campaign {i}"))?;
            entries.push(TraceEntry { at, budget, system });
        }
        Ok(Trace { entries })
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &std::path::Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Trace::from_json(&Json::parse(&text)?)
    }
}

/// Replay outcome for one campaign.
#[derive(Debug, Clone)]
pub struct ReplayRow {
    pub at: f64,
    pub budget: f64,
    pub makespan: f64,
    pub cost: f64,
    pub feasible: bool,
    /// Completion wall-clock (arrival + planning-ignored makespan).
    pub finish_at: f64,
}

/// Replay a trace through the planner (campaigns are independent — each
/// gets its own fleet, as in the paper's model).
pub fn replay(trace: &Trace) -> Vec<ReplayRow> {
    trace
        .entries
        .iter()
        .map(|e| {
            let r = crate::scheduler::Planner::new(&e.system).find(e.budget);
            ReplayRow {
                at: e.at,
                budget: e.budget,
                makespan: r.score.makespan,
                cost: r.score.cost,
                feasible: r.feasible,
                finish_at: e.at + r.score.makespan,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_trace_is_sorted_and_deterministic() {
        let t1 = Trace::synthetic(5, 10, 600.0);
        let t2 = Trace::synthetic(5, 10, 600.0);
        assert_eq!(t1.entries.len(), 10);
        for w in t1.entries.windows(2) {
            assert!(w[1].at >= w[0].at);
        }
        for (a, b) in t1.entries.iter().zip(&t2.entries) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.budget, b.budget);
            assert_eq!(a.system.tasks().len(), b.system.tasks().len());
        }
    }

    #[test]
    fn json_roundtrip() {
        let t = Trace::synthetic(7, 4, 300.0);
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back.entries.len(), 4);
        for (a, b) in t.entries.iter().zip(&back.entries) {
            assert_eq!(a.at, b.at);
            assert_eq!(a.budget, b.budget);
            assert_eq!(a.system.tasks().len(), b.system.tasks().len());
            assert_eq!(a.system.n_types(), b.system.n_types());
        }
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace::synthetic(9, 3, 100.0);
        let dir = std::env::temp_dir().join("botsched_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.json");
        t.save(&path).unwrap();
        let back = Trace::load(&path).unwrap();
        assert_eq!(back.entries.len(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn unsorted_trace_rejected() {
        let j = Json::parse(
            r#"{"campaigns":[
                {"at": 10, "budget": 5, "system": {"apps":[{"task_sizes":[1]}],
                  "instance_types":[{"cost_per_hour":5,"perf":[10]}]}},
                {"at": 5, "budget": 5, "system": {"apps":[{"task_sizes":[1]}],
                  "instance_types":[{"cost_per_hour":5,"perf":[10]}]}}
            ]}"#,
        )
        .unwrap();
        assert!(Trace::from_json(&j).is_err());
    }

    #[test]
    fn replay_produces_sane_rows() {
        let t = Trace::synthetic(11, 5, 200.0);
        let rows = replay(&t);
        assert_eq!(rows.len(), 5);
        for (r, e) in rows.iter().zip(&t.entries) {
            assert_eq!(r.at, e.at);
            assert!(r.finish_at >= r.at);
            assert!(r.makespan > 0.0);
            if r.feasible {
                assert!(r.cost <= r.budget + 1e-9);
            }
        }
        // Generated budgets are >= 1.1x the floor, so most should be feasible.
        assert!(rows.iter().filter(|r| r.feasible).count() >= 3);
    }
}
