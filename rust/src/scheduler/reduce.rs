//! Sec. IV-D `REDUCE`: lower the plan's cost by dismantling whole VMs.
//!
//! Moving a single task can add a billed hour on the receiving side, so
//! the cost-reduction step instead removes *entire* VMs, re-assigning all
//! of their tasks, and keeps a removal only if the plan's total cost
//! strictly drops.  Two modes (paper Sec. IV-D):
//!
//! * **local** — tasks may only move to VMs of the same instance type as
//!   the dismantled VM (used right after `INITIAL`, where each app has a
//!   uniform pool);
//! * **global** — tasks may move to any surviving VM.
//!
//! Candidates are tried from the lowest execution time upwards ("tries to
//! move all tasks from one VM with lowest execution time to others") and
//! the process repeats until the budget constraint holds or no removal
//! improves cost.

use super::assign_restricted;
use crate::model::{Plan, System};

/// Which VMs may receive the dismantled VM's tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReduceMode {
    /// Receivers must share the removed VM's instance type.
    Local,
    /// Any surviving VM may receive tasks.
    Global,
}

/// Run REDUCE until `cost <= budget` or no removal helps.  Returns the
/// number of VMs removed.
pub fn reduce(sys: &System, plan: &mut Plan, budget: f64, mode: ReduceMode) -> usize {
    let mut removed = 0usize;
    loop {
        if plan.cost(sys) <= budget + 1e-9 {
            break;
        }
        if !try_remove_one(sys, plan, mode) {
            break;
        }
        removed += 1;
    }
    removed
}

/// Attempt to dismantle one VM (lowest exec first); returns success.
fn try_remove_one(sys: &System, plan: &mut Plan, mode: ReduceMode) -> bool {
    if plan.n_vms() < 2 {
        return false;
    }
    let old_cost = plan.cost(sys);
    // Candidate victims ordered by ascending execution time.
    let mut order: Vec<usize> = (0..plan.n_vms()).collect();
    order.sort_by(|&a, &b| plan.vms[a].exec(sys).total_cmp(&plan.vms[b].exec(sys)));

    for victim in order {
        let receivers: Vec<usize> = (0..plan.n_vms())
            .filter(|&i| i != victim)
            .filter(|&i| match mode {
                ReduceMode::Local => plan.vms[i].it == plan.vms[victim].it,
                ReduceMode::Global => true,
            })
            .collect();
        if receivers.is_empty() {
            continue;
        }
        // Tentative removal on a scratch copy; commit only on cost win.
        // A genuine copy is wanted here (allow-listed boundary site of
        // the `disallowed-methods` gate): REDUCE's accept test needs the
        // untouched plan to fall back to.
        #[allow(clippy::disallowed_methods)]
        let mut scratch = plan.clone();
        let tasks = scratch.vms[victim].drain_tasks();
        // Route each task to the receiver needing the least time for it
        // (ASSIGN's criteria already encode that preference).
        assign_restricted(sys, &mut scratch, &tasks, &receivers);
        scratch.remove_vms(&[victim]);
        if scratch.cost(sys) < old_cost - 1e-9 {
            *plan = scratch;
            return true;
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder};
    use crate::scheduler::initial;
    use crate::workload::paper::table1_system;

    #[test]
    fn reduces_initial_plan_under_budget() {
        // With a boot overhead every provisioned-but-idle pool VM bills an
        // hour, so INITIAL (18 VMs at budget 60) grossly over-spends and
        // local REDUCE must dismantle VMs back under the budget.
        let sys = table1_system(300.0);
        let budget = 70.0;
        let mut plan = initial(&sys, budget);
        assert!(plan.cost(&sys) > budget); // INITIAL over-provisions
        reduce(&sys, &mut plan, budget, ReduceMode::Local);
        assert!(plan.cost(&sys) <= budget + 1e-9, "cost {} > {}", plan.cost(&sys), budget);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn initial_hour_packing_can_already_meet_budget() {
        // At o = 0 the ASSIGN criteria pack paid hours tightly enough that
        // the Table I workload's initial plan is already at the integer
        // cost floor (60 = 4x it_3 + 2x it_4 hours).
        let sys = table1_system(0.0);
        let plan = initial(&sys, 60.0);
        assert!(plan.cost(&sys) <= 70.0);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn noop_when_already_under_budget() {
        let sys = table1_system(0.0);
        let mut plan = initial(&sys, 60.0);
        reduce(&sys, &mut plan, 60.0, ReduceMode::Local);
        let cost = plan.cost(&sys);
        let n = plan.n_vms();
        assert_eq!(reduce(&sys, &mut plan, 60.0, ReduceMode::Global), 0);
        assert_eq!(plan.cost(&sys), cost);
        assert_eq!(plan.n_vms(), n);
    }

    #[test]
    fn local_mode_keeps_tasks_on_same_type() {
        let sys = SystemBuilder::new()
            .app("a", vec![10.0; 6])
            .instance_type("x", 5.0, vec![100.0])
            .instance_type("y", 6.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        // Three underused x VMs and one y VM; local reduce must merge the
        // x pool without touching y.
        for _ in 0..3 {
            plan.add_vm(&sys, InstanceTypeId(0));
        }
        plan.add_vm(&sys, InstanceTypeId(1));
        for (i, t) in sys.tasks().iter().enumerate() {
            plan.vms[i % 3].push_task(&sys, t.id);
        }
        reduce(&sys, &mut plan, 0.0, ReduceMode::Local); // force max reduction
        assert!(plan.vms.iter().filter(|vm| vm.it == InstanceTypeId(0)).all(|vm| !vm.is_empty() || true));
        // y VM must have received nothing.
        let y_vm = plan.vms.iter().find(|vm| vm.it == InstanceTypeId(1)).unwrap();
        assert!(y_vm.is_empty());
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn never_increases_cost() {
        let sys = table1_system(300.0);
        let mut plan = initial(&sys, 45.0);
        let before = plan.cost(&sys);
        reduce(&sys, &mut plan, 45.0, ReduceMode::Local);
        reduce(&sys, &mut plan, 45.0, ReduceMode::Global);
        assert!(plan.cost(&sys) <= before + 1e-9);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn single_vm_cannot_reduce() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0])
            .instance_type("x", 5.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        plan.vms[v].push_task(&sys, crate::model::TaskId(0));
        assert_eq!(reduce(&sys, &mut plan, 0.0, ReduceMode::Global), 0);
        assert_eq!(plan.n_vms(), 1);
    }
}
