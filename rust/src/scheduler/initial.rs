//! Sec. IV-C `INITIAL`: build the first (budget-oblivious) plan.
//!
//! For every application the *best* instance type is selected —
//! lexicographically minimal `(P[it, A], c_it)` among the types whose
//! hourly price fits the budget — and the **whole budget** is spent on a
//! pool of `floor(B / c_it)` VMs of that type.  With several applications
//! this over-provisions (roughly `M x B`); Algorithm 1 follows up with a
//! local `REDUCE` to pull the cost back under the budget.

use super::assign;
use crate::model::{Plan, System, TaskId};

/// Create the initial plan and assign every task (paper lines 2-3 of
/// Algorithm 1: `INITIAL` followed by `ASSIGN`).
pub fn initial(sys: &System, budget: f64) -> Plan {
    let mut plan = Plan::new();
    for app in &sys.apps {
        if app.is_empty() {
            continue;
        }
        let it = sys.best_type_for_app(app.id, budget);
        let rate = sys.rate(it);
        // floor(B / c_it), but at least one VM so every app has a pool.
        let num = ((budget / rate).floor() as usize).max(1);
        for _ in 0..num {
            plan.add_vm(sys, it);
        }
    }
    let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
    assign(sys, &mut plan, &tasks);
    plan
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn pools_sized_by_whole_budget() {
        let sys = table1_system(0.0);
        let plan = initial(&sys, 40.0);
        // A1 best: it_3 (10 s/u, cost 10, beats it_4 tie by order) -> 4 VMs
        // A2 best: it_4 (9 s/u) -> 4 VMs; A3 best: it_3 (9 s/u) -> 4 VMs.
        let mix = plan.vm_mix(&sys);
        assert_eq!(mix[0], 0);
        assert_eq!(mix[1], 0);
        assert_eq!(mix[2] + mix[3], 12);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn tiny_budget_still_yields_a_plan() {
        let sys = table1_system(0.0);
        let plan = initial(&sys, 1.0); // below every hourly price
        assert!(plan.n_vms() >= 3);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn tasks_go_to_their_apps_best_type() {
        let sys = table1_system(0.0);
        let plan = initial(&sys, 40.0);
        // Every A2 task must sit on a memory-optimised VM (it_4, fastest).
        for vm in &plan.vms {
            for &t in vm.tasks() {
                if sys.task(t).app.0 == 1 {
                    assert_eq!(vm.it.0, 3, "A2 task on non-it4 VM");
                }
            }
        }
    }
}
