//! Sec. V-A comparison baselines.
//!
//! * **MI — Minimising Individual task execution time**: run `ADD` with
//!   the full budget (buys the best-average-performance affordable type
//!   until the money runs out), then assign and balance.
//! * **MP — Maximising Parallelism**: buy `floor(B / c_min)` VMs of the
//!   cheapest instance type, then assign and balance.
//!
//! Neither baseline manages billed hours (no REDUCE/SPLIT/REPLACE), which
//! is exactly why they degrade at tight budgets in Fig. 1.

use super::{add_vms, assign, balance};
use crate::model::{Plan, System, TaskId};

/// MI: ADD with the full budget + ASSIGN + BALANCE.
pub fn minimise_individual(sys: &System, budget: f64) -> Plan {
    let mut plan = Plan::new();
    add_vms(sys, &mut plan, budget);
    finish(sys, &mut plan);
    plan
}

/// MP: as many cheapest-type VMs as the budget buys + ASSIGN + BALANCE.
pub fn maximise_parallelism(sys: &System, budget: f64) -> Plan {
    let mut plan = Plan::new();
    let it = sys.cheapest_type();
    let n = (budget / sys.rate(it)).floor() as usize;
    for _ in 0..n {
        plan.add_vm(sys, it);
    }
    finish(sys, &mut plan);
    plan
}

fn finish(sys: &System, plan: &mut Plan) {
    if plan.is_empty() {
        // Budget below every hourly price: provision a single cheapest VM
        // so the workload still completes (reported as infeasible).
        plan.add_vm(sys, sys.cheapest_type());
    }
    let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
    assign(sys, plan, &tasks);
    // The baselines spread without a cost envelope (the paper's MI/MP
    // simply distribute over the purchased VMs); feasibility is assessed
    // afterwards against realized cost.
    balance(sys, plan, f64::INFINITY);
    plan.drop_empty_vms();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn mp_buys_only_cheapest_type() {
        let sys = table1_system(0.0);
        let plan = maximise_parallelism(&sys, 45.0);
        let mix = plan.vm_mix(&sys);
        assert_eq!(mix[1] + mix[2] + mix[3], 0);
        assert!(mix[0] <= 9); // floor(45/5), minus any dropped empties
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn mi_prefers_it4() {
        let sys = table1_system(0.0);
        let plan = minimise_individual(&sys, 50.0);
        let mix = plan.vm_mix(&sys);
        assert!(mix[3] >= 4, "MI must buy it_4 first, got {mix:?}");
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn tiny_budget_still_completes_workload() {
        let sys = table1_system(0.0);
        for plan in [minimise_individual(&sys, 1.0), maximise_parallelism(&sys, 1.0)] {
            assert!(plan.validate_partition(&sys).is_ok());
            assert!(plan.n_vms() >= 1);
        }
    }

    #[test]
    fn mp_parallelism_beats_mi_vm_count() {
        let sys = table1_system(0.0);
        let mp = maximise_parallelism(&sys, 60.0);
        let mi = minimise_individual(&sys, 60.0);
        assert!(mp.n_vms() >= mi.n_vms());
    }
}
