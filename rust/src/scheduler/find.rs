//! Algorithm 1 `FIND` (`DO_ASSIGNMENT`): the fixed-point iteration that
//! ties the phases together.
//!
//! ```text
//! VM  <- INITIAL(A, IT, B); ASSIGN; REDUCE(local, B)
//! loop:
//!   REDUCE(global, B); ADD(B - cost); BALANCE; SPLIT; REPLACE(max(B, cost), 1)
//!   accept if cost or exec strictly improved, else return the stored plan
//! ```
//!
//! Deviations from the paper's pseudo-code, all documented in DESIGN.md:
//!
//! * an iteration cap guards against cost/exec oscillation (the paper's
//!   accept test is an OR of two objectives, which does not by itself
//!   guarantee termination);
//! * the stored ("best") plan additionally tracks budget feasibility —
//!   among feasible plans the paper's accept rule is applied unchanged,
//!   and an infeasible plan never replaces a feasible one (otherwise
//!   Algorithm 1 could return a plan violating eq. 9);
//! * every phase is individually toggleable for the ablation benchmarks.

use super::balance::balance_arena_threaded;
use super::replace::{replace_arena_opts, ReplaceOpts};
use super::{add_vms, initial, reduce, split, ReduceMode};
use crate::eval::{DeltaBatch, NativeEvaluator, PlanArena, PlanEvaluator};
use crate::model::{Plan, PlanScore, System};
use crate::util::CancelToken;

/// Phase toggles + iteration cap (defaults reproduce the paper).
#[derive(Debug, Clone)]
pub struct PlannerConfig {
    pub max_iters: usize,
    pub enable_reduce: bool,
    pub enable_add: bool,
    pub enable_balance: bool,
    pub enable_split: bool,
    pub enable_replace: bool,
    /// `k` handed to REPLACE (Algorithm 1 uses 1).
    pub replace_k: usize,
}

impl Default for PlannerConfig {
    fn default() -> Self {
        Self {
            max_iters: 64,
            enable_reduce: true,
            enable_add: true,
            enable_balance: true,
            enable_split: true,
            enable_replace: true,
            replace_k: 1,
        }
    }
}

/// Outcome of a FIND run.
#[derive(Debug, Clone)]
pub struct FindReport {
    pub plan: Plan,
    pub score: PlanScore,
    /// Whether the returned plan satisfies eq. 9 for the requested budget.
    pub feasible: bool,
    /// Iterations of the optimisation loop actually executed.
    pub iterations: usize,
}

/// The paper's heuristic planner: couples the Section IV phases with a
/// [`PlanEvaluator`] used for all end-of-iteration and REPLACE candidate
/// scoring.
pub struct Planner<'a> {
    pub sys: &'a System,
    pub evaluator: &'a dyn PlanEvaluator,
    pub config: PlannerConfig,
    /// Cooperative cancellation, polled once per FIND iteration (and in
    /// REPLACE's candidate enumeration).  The default token never fires.
    pub cancel: CancelToken,
    /// Intra-solve thread count handed to BALANCE's move search and
    /// REPLACE's candidate generation/scoring (0 = auto, 1 = sequential;
    /// default 1).  Plans are bit-identical at any value — pinned by the
    /// `parallel_parity` suite.  Callers running *multiple* planners
    /// concurrently (multistart) must keep this at 1; see
    /// [`crate::util::nested_inner_threads`].
    pub threads: usize,
}

impl<'a> Planner<'a> {
    pub fn new(sys: &'a System) -> Self {
        Self {
            sys,
            evaluator: &NativeEvaluator,
            config: PlannerConfig::default(),
            cancel: CancelToken::default(),
            threads: 1,
        }
    }

    pub fn with_evaluator(sys: &'a System, evaluator: &'a dyn PlanEvaluator) -> Self {
        Self {
            sys,
            evaluator,
            config: PlannerConfig::default(),
            cancel: CancelToken::default(),
            threads: 1,
        }
    }

    pub fn with_config(mut self, config: PlannerConfig) -> Self {
        self.config = config;
        self
    }

    pub fn with_cancel(mut self, cancel: CancelToken) -> Self {
        self.cancel = cancel;
        self
    }

    /// Set the intra-solve thread count (0 = auto, 1 = sequential).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Algorithm 1: find an execution plan for `budget`.
    pub fn find(&self, budget: f64) -> FindReport {
        let sys = self.sys;
        let cfg = &self.config;

        // Lines 2-4: INITIAL + ASSIGN + local REDUCE.
        let mut plan = initial(sys, budget);
        if cfg.enable_reduce {
            reduce(sys, &mut plan, budget, ReduceMode::Local);
        }
        plan.drop_empty_vms();

        // Lines 5-7: stored best (cost'/exec' start at +inf, so the first
        // iteration always stores).  These two accept-store clones are the
        // loop's only plan copies — allow-listed boundary sites of the
        // `disallowed-methods` gate.
        #[allow(clippy::disallowed_methods)]
        let mut best = plan.clone();
        let mut best_score = PlanScore { makespan: f64::INFINITY, cost: f64::INFINITY };
        let mut best_feasible = false;

        // One arena reused across phases and iterations: BALANCE and
        // REPLACE mutate it in place (contiguous rows, free-list VM
        // churn) and store back only when they changed something.
        let mut arena = PlanArena::new(sys);

        let mut iterations = 0usize;
        for _ in 0..cfg.max_iters {
            iterations += 1;

            // Line 9: global REDUCE.
            if cfg.enable_reduce {
                reduce(sys, &mut plan, budget, ReduceMode::Global);
            }
            // Line 10: ADD with the remaining budget.
            if cfg.enable_add {
                let cost = plan.cost(sys);
                if cost < budget {
                    add_vms(sys, &mut plan, budget - cost);
                }
            }
            // Line 11: BALANCE within the budget envelope (loading the
            // VMs ADD just provisioned raises realized cost up to ADD's
            // one-hour estimates, but never past max(B, current cost)).
            if cfg.enable_balance {
                let cap = budget.max(plan.cost(sys));
                arena.load_plan(&plan);
                if balance_arena_threaded(sys, &mut arena, cap, self.threads) > 0 {
                    arena.store_plan(&mut plan);
                }
            }
            // Line 12: SPLIT (keep VMs under one billed hour).
            if cfg.enable_split {
                split(sys, &mut plan, budget);
            }
            // Line 13: REPLACE with the relaxed temporary budget
            // max(B, cost) — lets an over-budget plan trade down.
            if cfg.enable_replace {
                let tmp_budget = budget.max(plan.cost(sys));
                arena.load_plan(&plan);
                if replace_arena_opts(
                    sys,
                    &mut arena,
                    tmp_budget,
                    cfg.replace_k,
                    self.evaluator,
                    &self.cancel,
                    &ReplaceOpts { threads: self.threads, ..Default::default() },
                ) {
                    arena.store_plan(&mut plan);
                }
            }
            // ADD may have provisioned VMs BALANCE did not use; they
            // would bill an idle hour each (o > 0) or distort Fig. 2.
            plan.drop_empty_vms();

            // Line 14: accept on strict improvement of either objective,
            // scored through the evaluator (the XLA artifact in the
            // coordinator) via the zero-clone delta path, with the
            // feasibility refinement.
            let score = self.evaluator.eval_deltas(&DeltaBatch::from_plan(sys, &plan))[0];
            let feasible = score.satisfies(budget);
            let accept = match (feasible, best_feasible) {
                (true, false) => true,
                (false, true) => false,
                _ => score.improves(&best_score),
            };
            if accept {
                #[allow(clippy::disallowed_methods)]
                {
                    best = plan.clone();
                }
                best_score = score;
                best_feasible = feasible;
            } else {
                break;
            }
            // Cooperative cancellation: stop after a full iteration has
            // been stored, so a cancelled FIND still returns a scored
            // plan (the best one seen so far).
            if self.cancel.is_cancelled() {
                break;
            }
        }

        FindReport { plan: best, score: best_score, feasible: best_feasible, iterations }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::baselines::{maximise_parallelism, minimise_individual};
    use crate::workload::paper::{table1_system, BUDGETS};

    #[test]
    fn returns_valid_partition_across_budgets() {
        let sys = table1_system(0.0);
        for &b in BUDGETS {
            let report = Planner::new(&sys).find(b);
            assert!(
                report.plan.validate_partition(&sys).is_ok(),
                "budget {b}: invalid partition"
            );
            assert!(report.iterations >= 1);
        }
    }

    #[test]
    fn feasible_whenever_the_workload_admits_it() {
        let sys = table1_system(0.0);
        // At generous budgets the plan must be feasible.
        for &b in &[70.0, 80.0, 100.0, 150.0] {
            let report = Planner::new(&sys).find(b);
            assert!(report.feasible, "budget {b} should be satisfiable");
            assert!(report.score.cost <= b + 1e-9);
        }
    }

    #[test]
    fn beats_or_matches_baselines_when_all_feasible() {
        let sys = table1_system(0.0);
        for &b in &[70.0, 80.0, 90.0, 110.0] {
            let ours = Planner::new(&sys).find(b);
            for (name, base) in
                [("MI", minimise_individual(&sys, b)), ("MP", maximise_parallelism(&sys, b))]
            {
                let bs = base.score(&sys);
                if bs.satisfies(b) && ours.feasible {
                    assert!(
                        ours.score.makespan <= bs.makespan * 1.05 + 1e-6,
                        "budget {b}: ours {} vs {name} {}",
                        ours.score.makespan,
                        bs.makespan
                    );
                }
            }
        }
    }

    #[test]
    fn ablation_toggles_run() {
        let sys = table1_system(0.0);
        for phase in 0..5 {
            let mut cfg = PlannerConfig::default();
            match phase {
                0 => cfg.enable_reduce = false,
                1 => cfg.enable_add = false,
                2 => cfg.enable_balance = false,
                3 => cfg.enable_split = false,
                _ => cfg.enable_replace = false,
            }
            let report = Planner::new(&sys).with_config(cfg).find(80.0);
            assert!(report.plan.validate_partition(&sys).is_ok(), "phase {phase} off");
        }
    }

    #[test]
    fn overhead_respected() {
        let sys = table1_system(120.0);
        let report = Planner::new(&sys).find(80.0);
        assert!(report.plan.validate_partition(&sys).is_ok());
        // Makespan must include at least the boot overhead.
        assert!(report.score.makespan >= 120.0);
    }
}
