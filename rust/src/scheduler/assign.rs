//! Sec. IV-A `ASSIGN`: route a list of tasks onto a list of VMs.
//!
//! For each task the receiving VM is selected by three criteria:
//!
//! 1. the VM should not increase its billed cost by taking the task
//!    (it still fits in the VM's already-paid hours);
//! 2. the VM should need the least time to execute the task
//!    (its instance type is fastest for the task's application);
//! 3. the VM should have the lowest current execution time.
//!
//! The paper enumerates them i-iii in that order but its own descriptions
//! of `INITIAL` ("tasks are assigned to the best instance type") and
//! `REDUCE` ("tries to move tasks to VMs whose require least time to
//! execute them") only hold when the *least-time* criterion dominates —
//! with the cost criterion first, a paid-but-slow VM would swallow every
//! task of every application.  We therefore rank by
//! `(task time, cost-free, current load)` lexicographically and document
//! the resolution in DESIGN.md "Paper ambiguities".  Within a pool of
//! equally fast VMs this still fills already-paid hours before opening a
//! new one (criterion 1), which is the cost behaviour the paper wants;
//! `BALANCE` subsequently evens out finish times.

use crate::model::{Plan, System, TaskId};

/// Assign `tasks` to any VM of `plan`. Tasks are routed one at a time in
/// the given order.
pub fn assign(sys: &System, plan: &mut Plan, tasks: &[TaskId]) {
    let all: Vec<usize> = (0..plan.n_vms()).collect();
    assign_restricted(sys, plan, tasks, &all);
}

/// Assign `tasks`, restricted to the VM indices in `allowed` (used by
/// REDUCE's local mode).
///
/// Panics if `allowed` is empty while `tasks` is not — callers must
/// guarantee a destination exists.
pub fn assign_restricted(sys: &System, plan: &mut Plan, tasks: &[TaskId], allowed: &[usize]) {
    if tasks.is_empty() {
        return;
    }
    assert!(!allowed.is_empty(), "ASSIGN: no candidate VMs for {} tasks", tasks.len());
    for &task in tasks {
        let vm_idx = select_vm(sys, plan, task, allowed);
        plan.vms[vm_idx].push_task(sys, task);
    }
}

/// Pick the receiving VM for one task per the ASSIGN criteria.
fn select_vm(sys: &System, plan: &Plan, task: TaskId, allowed: &[usize]) -> usize {
    let mut best: Option<(f64, bool, f64, usize)> = None;
    for &vi in allowed {
        let vm = &plan.vms[vi];
        let t_time = vm.task_time(sys, task); // criterion ii (primary)
        let free = vm.fits_without_cost_increase(sys, task); // criterion i
        let load = vm.exec(sys); // criterion iii
        let key = (t_time, free, load, vi);
        let better = match &best {
            None => true,
            Some(cur) => {
                (key.0, !key.1, key.2, key.3) < (cur.0, !cur.1, cur.2, cur.3)
            }
        };
        if better {
            best = Some(key);
        }
    }
    best.expect("allowed non-empty").3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder};
    use crate::scheduler::balance;

    fn sys() -> System {
        SystemBuilder::new()
            .app("cpuish", vec![1.0, 1.0, 1.0, 1.0])
            .app("memish", vec![2.0, 2.0])
            .instance_type("small", 5.0, vec![20.0, 24.0])
            .instance_type("cpu", 10.0, vec![10.0, 15.0])
            .instance_type("mem", 10.0, vec![10.0, 9.0])
            .build()
            .unwrap()
    }

    #[test]
    fn routes_to_fastest_type() {
        let s = sys();
        let mut p = Plan::new();
        p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(2));
        // app1 ("memish") tasks are fastest on "mem" (9 s/u).
        let memish: Vec<TaskId> = s.tasks().iter().filter(|t| t.app.0 == 1).map(|t| t.id).collect();
        assign(&s, &mut p, &memish);
        assert_eq!(p.vms[2].len(), 2);
        assert_eq!(p.vms[0].len() + p.vms[1].len(), 0);
    }

    #[test]
    fn fills_paid_hours_first_then_balance_spreads() {
        let s = sys();
        let mut p = Plan::new();
        // two identical-speed VMs for app0: "cpu" and "mem" both 10 s/u.
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(2));
        let cpuish: Vec<TaskId> = s.tasks().iter().filter(|t| t.app.0 == 0).map(|t| t.id).collect();
        assign(&s, &mut p, &cpuish);
        // Criterion i (within equal speed): the first VM's paid hour
        // swallows all four 10s tasks...
        assert_eq!(p.vms[0].len(), 4);
        assert_eq!(p.vms[1].len(), 0);
        // ...and BALANCE then evens them out.
        balance(&s, &mut p, f64::INFINITY);
        assert_eq!(p.vms[0].len(), 2);
        assert_eq!(p.vms[1].len(), 2);
    }

    #[test]
    fn restricted_assign_ignores_other_vms() {
        let s = sys();
        let mut p = Plan::new();
        p.add_vm(&s, InstanceTypeId(2)); // fastest for memish, but not allowed
        p.add_vm(&s, InstanceTypeId(0));
        let memish: Vec<TaskId> = s.tasks().iter().filter(|t| t.app.0 == 1).map(|t| t.id).collect();
        assign_restricted(&s, &mut p, &memish, &[1]);
        assert_eq!(p.vms[1].len(), 2);
        assert_eq!(p.vms[0].len(), 0);
    }

    #[test]
    fn fastest_type_wins_over_paid_hours() {
        // Criterion ii dominates criterion i: a faster empty VM (new billed
        // hour) beats a slower VM with paid room.  See the module docs for
        // why the paper's i-iii ordering is resolved this way.
        let s = SystemBuilder::new()
            .app("a", vec![100.0, 1.0])
            .instance_type("slow", 5.0, vec![30.0])
            .instance_type("fast", 10.0, vec![1.0])
            .overhead(0.0)
            .build()
            .unwrap();
        let mut p = Plan::new();
        let slow = p.add_vm(&s, InstanceTypeId(0));
        let fast = p.add_vm(&s, InstanceTypeId(1));
        p.vms[slow].push_task(&s, TaskId(0)); // 3000s -> inside 1 paid hour
        assign(&s, &mut p, &[TaskId(1)]);
        assert_eq!(p.vms[fast].len(), 1);
    }

    #[test]
    fn equal_speed_prefers_cost_free_vm() {
        // Between equally fast VMs, the one with paid room wins even if
        // more loaded (criterion i before iii).
        let s = SystemBuilder::new()
            .app("a", vec![10.0, 10.0])
            .instance_type("x", 5.0, vec![10.0])
            .instance_type("y", 6.0, vec![10.0])
            .build()
            .unwrap();
        let mut p = Plan::new();
        let x = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.vms[x].push_task(&s, TaskId(0)); // x now paid, loaded 100s
        assign(&s, &mut p, &[TaskId(1)]);
        assert_eq!(p.vms[x].len(), 2, "paid x beats empty y at equal speed");
    }

    #[test]
    #[should_panic(expected = "no candidate VMs")]
    fn empty_allowed_panics() {
        let s = sys();
        let mut p = Plan::new();
        assign(&s, &mut p, &[TaskId(0)]);
    }

    #[test]
    fn empty_tasks_is_noop() {
        let s = sys();
        let mut p = Plan::new();
        assign(&s, &mut p, &[]); // must not panic despite no VMs
        assert_eq!(p.n_vms(), 0);
    }
}
