//! Sec. IV-B `BALANCE`: even out VM finish times.
//!
//! The overall execution time is the slowest VM's (eq. 7), so tasks are
//! moved off the highest-execution-time VM onto others "as long as the
//! overall execution time does not increase".  Two implementation choices
//! make the paper's sketch terminating and budget-safe:
//!
//! * a move is accepted only if both the source's and the receiver's new
//!   execution times stay **strictly below** the current makespan (plain
//!   "does not increase" admits infinite swap cycles);
//! * the plan's total billed cost after the move must stay within
//!   `cost_cap`.  Algorithm 1 passes `max(B, current cost)` — BALANCE is
//!   what loads the empty VMs that `ADD` just provisioned (which *raises*
//!   realized cost up to ADD's one-hour estimates), but it must not push
//!   the plan past the budget envelope.  The baselines pass `+inf`,
//!   matching the paper's plain "evenly distributed" description.
//!
//! The move search runs on [`PlanArena`] ([`balance_arena`]): the inner
//! loop walks the arena's contiguous per-VM caches instead of a
//! `Vec<Vm>`, and FIND reuses one arena across phases.  [`balance`] is
//! the `Plan`-level wrapper (load → balance → store); both produce
//! bit-identical plans to the original materialising implementation —
//! pinned by the `arena_parity` suite.

use crate::eval::PlanArena;
use crate::model::{billed_cost, Plan, System, TaskId};

/// Balance tasks between VMs subject to the cost cap.  Returns the number
/// of task moves applied.
///
/// `Plan`-level wrapper around [`balance_arena`]; the store-back is
/// skipped when no move was found.
pub fn balance(sys: &System, plan: &mut Plan, cost_cap: f64) -> usize {
    let mut arena = PlanArena::from_plan(sys, plan);
    let moves = balance_arena(sys, &mut arena, cost_cap);
    if moves > 0 {
        arena.store_plan(plan);
    }
    moves
}

/// BALANCE on arena state, in place.  Returns the number of task moves
/// applied.
///
/// The per-VM execution times are collected once and maintained
/// incrementally across loop iterations (a move only changes the source
/// and receiver VM), so each iteration costs O(tasks·VMs) for the move
/// search, not an extra O(VMs) re-collection per attempt.
pub fn balance_arena(sys: &System, arena: &mut PlanArena, cost_cap: f64) -> usize {
    let mut moves = 0usize;
    // Upper bound on useful moves; guards against pathological cycling.
    let budget_moves = arena.n_assigned() * 4 + 16;
    let mut total_cost = arena.cost(sys);
    let mut execs: Vec<f64> = (0..arena.n_vms()).map(|p| arena.exec_at(sys, p)).collect();
    while moves < budget_moves {
        match best_rebalancing_move(sys, arena, &execs, total_cost, cost_cap) {
            Some((from, to, task, new_cost)) => {
                arena.move_task(sys, from, to, task);
                execs[from] = arena.exec_at(sys, from);
                execs[to] = arena.exec_at(sys, to);
                total_cost = new_cost;
                moves += 1;
            }
            None => break,
        }
    }
    moves
}

/// Find the single best (source, receiver, task) move off the current
/// makespan VM, or `None` if no move strictly helps.  `execs` carries the
/// caller-maintained per-VM execution times.  Returns the plan's total
/// cost after the move as the fourth element.
fn best_rebalancing_move(
    sys: &System,
    arena: &PlanArena,
    execs: &[f64],
    total_cost: f64,
    cost_cap: f64,
) -> Option<(usize, usize, TaskId, f64)> {
    if arena.n_vms() < 2 {
        return None;
    }
    let (from, &makespan) = execs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1))?;
    if arena.is_empty_at(from) {
        return None;
    }
    let src_it = arena.it_at(from);
    let src_work = arena.work_at(from);
    let src_len = arena.len_at(from);
    let src_cost = arena.cost_at(sys, from);

    let mut best: Option<(f64, usize, TaskId, f64)> = None;
    for &task in arena.tasks_at(from) {
        let t_src = sys.exec_time(src_it, task);
        let src_new_exec = if src_len == 1 && sys.overhead == 0.0 {
            0.0
        } else {
            sys.overhead + src_work - t_src
        };
        for to in 0..arena.n_vms() {
            if to == from {
                continue;
            }
            let dst_it = arena.it_at(to);
            let dst_new_exec = sys.overhead + arena.work_at(to) + sys.exec_time(dst_it, task);
            // Strict improvement on both ends: the pair's new max must
            // drop below the current makespan.
            let pair_max = src_new_exec.max(dst_new_exec);
            if pair_max >= makespan - 1e-9 {
                continue;
            }
            // Cost cap: total billed cost after the move stays bounded.
            let src_new_cost = billed_cost(src_new_exec, sys.rate(src_it), sys.hour, sys.billing);
            let dst_new_cost = billed_cost(dst_new_exec, sys.rate(dst_it), sys.hour, sys.billing);
            let new_total =
                total_cost + (src_new_cost - src_cost) + (dst_new_cost - arena.cost_at(sys, to));
            if new_total > cost_cap + 1e-9 {
                continue;
            }
            if best.as_ref().is_none_or(|(b, _, _, _)| pair_max < *b) {
                best = Some((pair_max, to, task, new_total));
            }
        }
    }
    best.map(|(_, to, task, new_cost)| (from, to, task, new_cost))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    fn sys_uniform(n_tasks: usize) -> System {
        SystemBuilder::new()
            .app("a", vec![1.0; n_tasks])
            .instance_type("x", 5.0, vec![100.0])
            .instance_type("y", 5.000001, vec![100.0])
            .build()
            .unwrap()
    }

    #[test]
    fn evens_out_two_vms() {
        let s = sys_uniform(8);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let before = p.exec(&s);
        let moves = balance(&s, &mut p, f64::INFINITY);
        assert!(moves > 0);
        assert!(p.exec(&s) < before);
        assert_eq!(p.vms[0].len(), 4);
        assert_eq!(p.vms[1].len(), 4);
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn cost_cap_blocks_spreading_to_unpaid_vm() {
        let s = sys_uniform(8);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id); // 800s -> cost 5
        }
        // Cap at the current cost: loading the empty VM costs ~5 more.
        assert_eq!(balance(&s, &mut p, 5.0), 0);
        assert_eq!(p.vms[1].len(), 0);
        // With cap 10.000001 the spread is allowed.
        assert!(balance(&s, &mut p, 10.01) > 0);
    }

    #[test]
    fn never_increases_makespan_and_respects_cap() {
        let s = SystemBuilder::new()
            .app("a", vec![3.0, 1.0, 4.0, 1.0, 5.0, 2.0])
            .app("b", vec![2.0, 2.0, 2.0])
            .instance_type("small", 5.0, vec![200.0, 300.0])
            .instance_type("cpu", 10.0, vec![100.0, 150.0])
            .overhead(30.0)
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let before = p.score(&s);
        let cap = before.cost + 20.0;
        balance(&s, &mut p, cap);
        let after = p.score(&s);
        assert!(after.makespan <= before.makespan + 1e-9);
        assert!(after.cost <= cap + 1e-9);
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn incremental_execs_stay_in_sync_with_fresh_recomputation() {
        // Run a multi-move balance and verify the plan it converges to is
        // a fixed point: re-running with freshly collected exec times
        // finds no further move.
        let s = SystemBuilder::new()
            .app("a", vec![3.0, 1.0, 4.0, 1.0, 5.0, 2.0, 6.0, 1.0])
            .app("b", vec![2.0, 2.0, 2.0, 3.0])
            .instance_type("small", 5.0, vec![200.0, 300.0])
            .instance_type("cpu", 10.0, vec![100.0, 150.0])
            .overhead(30.0)
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let moves = balance(&s, &mut p, f64::INFINITY);
        assert!(moves > 1, "scenario must exercise multiple iterations");
        assert_eq!(balance(&s, &mut p, f64::INFINITY), 0, "must converge to a fixed point");
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn single_vm_is_noop() {
        let s = sys_uniform(3);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        assert_eq!(balance(&s, &mut p, f64::INFINITY), 0);
    }

    #[test]
    fn balanced_input_is_fixed_point() {
        let s = sys_uniform(4);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.vms[v0].push_task(&s, TaskId(0));
        p.vms[v0].push_task(&s, TaskId(1));
        p.vms[v1].push_task(&s, TaskId(2));
        p.vms[v1].push_task(&s, TaskId(3));
        assert_eq!(balance(&s, &mut p, f64::INFINITY), 0);
    }

    #[test]
    fn arena_level_entry_balances_in_place() {
        let s = sys_uniform(8);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let mut arena = PlanArena::from_plan(&s, &p);
        let moves = balance_arena(&s, &mut arena, f64::INFINITY);
        assert!(moves > 0);
        assert_eq!(arena.len_at(0), 4);
        assert_eq!(arena.len_at(1), 4);
        assert!(arena.to_plan().validate_partition(&s).is_ok());
    }
}
