//! Sec. IV-B `BALANCE`: even out VM finish times.
//!
//! The overall execution time is the slowest VM's (eq. 7), so tasks are
//! moved off the highest-execution-time VM onto others "as long as the
//! overall execution time does not increase".  Two implementation choices
//! make the paper's sketch terminating and budget-safe:
//!
//! * a move is accepted only if both the source's and the receiver's new
//!   execution times stay **strictly below** the current makespan (plain
//!   "does not increase" admits infinite swap cycles);
//! * the plan's total billed cost after the move must stay within
//!   `cost_cap`.  Algorithm 1 passes `max(B, current cost)` — BALANCE is
//!   what loads the empty VMs that `ADD` just provisioned (which *raises*
//!   realized cost up to ADD's one-hour estimates), but it must not push
//!   the plan past the budget envelope.  The baselines pass `+inf`,
//!   matching the paper's plain "evenly distributed" description.
//!
//! The move search runs on [`PlanArena`] ([`balance_arena`]): the inner
//! loop walks the arena's contiguous per-VM caches instead of a
//! `Vec<Vm>`, and FIND reuses one arena across phases.  [`balance`] is
//! the `Plan`-level wrapper (load → balance → store); both produce
//! bit-identical plans to the original materialising implementation —
//! pinned by the `arena_parity` suite.
//!
//! **Threading** ([`balance_arena_threaded`]): each iteration's move
//! search scans `tasks(makespan VM) × destinations` candidate moves —
//! the per-iteration hot loop.  The task axis is split into contiguous
//! ranges scanned concurrently on the [`crate::util::parallel`] pool;
//! each range reports its own first strict minimum (same scan order as
//! the sequential loop) and the ranges merge **in range order with a
//! strict `<`**, which reproduces the sequential rule — *first*
//! occurrence of the global minimum wins — exactly.  Plans are therefore
//! bit-identical at any thread count (`parallel_parity` suite); the
//! size threshold below which the scan stays inline is a pure
//! performance knob.

use crate::eval::PlanArena;
use crate::model::{billed_cost, InstanceTypeId, Plan, System, TaskId};
use crate::util::{parallel_map, resolve_threads};

/// Balance tasks between VMs subject to the cost cap.  Returns the number
/// of task moves applied.
///
/// `Plan`-level wrapper around [`balance_arena`]; the store-back is
/// skipped when no move was found.
pub fn balance(sys: &System, plan: &mut Plan, cost_cap: f64) -> usize {
    let mut arena = PlanArena::from_plan(sys, plan);
    let moves = balance_arena(sys, &mut arena, cost_cap);
    if moves > 0 {
        arena.store_plan(plan);
    }
    moves
}

/// BALANCE on arena state, in place.  Returns the number of task moves
/// applied.
///
/// Sequential entry point — [`balance_arena_threaded`] with one thread.
pub fn balance_arena(sys: &System, arena: &mut PlanArena, cost_cap: f64) -> usize {
    balance_arena_threaded(sys, arena, cost_cap, 1)
}

/// BALANCE on arena state with an intra-search thread count (0 = auto,
/// 1 = sequential).  Returns the number of task moves applied.
///
/// The per-VM execution times are collected once and maintained
/// incrementally across loop iterations (a move only changes the source
/// and receiver VM), so each iteration costs O(tasks·VMs) for the move
/// search, not an extra O(VMs) re-collection per attempt.  The move
/// search itself is chunked over the makespan VM's task list when
/// `threads > 1` — bit-identical to the sequential scan (see module
/// doc).
pub fn balance_arena_threaded(
    sys: &System,
    arena: &mut PlanArena,
    cost_cap: f64,
    threads: usize,
) -> usize {
    let mut moves = 0usize;
    // Upper bound on useful moves; guards against pathological cycling.
    let budget_moves = arena.n_assigned() * 4 + 16;
    let mut total_cost = arena.cost(sys);
    let mut execs: Vec<f64> = (0..arena.n_vms()).map(|p| arena.exec_at(sys, p)).collect();
    while moves < budget_moves {
        match best_rebalancing_move(sys, arena, &execs, total_cost, cost_cap, threads) {
            Some((from, to, task, new_cost)) => {
                arena.move_task(sys, from, to, task);
                execs[from] = arena.exec_at(sys, from);
                execs[to] = arena.exec_at(sys, to);
                total_cost = new_cost;
                moves += 1;
            }
            None => break,
        }
    }
    moves
}

/// Below this many tasks on the makespan VM the move search stays
/// inline: the scan is too cheap to amortise handing chunks to the pool.
const MIN_CHUNKED_TASKS: usize = 16;

/// Shared read-only context for one move search: everything the per-task
/// scan needs besides the task itself.
struct ScanCtx<'a> {
    sys: &'a System,
    arena: &'a PlanArena,
    from: usize,
    makespan: f64,
    src_it: InstanceTypeId,
    src_work: f64,
    src_len: usize,
    src_cost: f64,
    total_cost: f64,
    cost_cap: f64,
}

impl ScanCtx<'_> {
    /// Scan a contiguous slice of the source VM's tasks in order and
    /// return its *first* strict minimum `(pair_max, to, task,
    /// new_total)` — the same selection rule the historical sequential
    /// loop applied to the full task list.
    fn scan(&self, tasks: &[TaskId]) -> Option<(f64, usize, TaskId, f64)> {
        let sys = self.sys;
        let arena = self.arena;
        let mut best: Option<(f64, usize, TaskId, f64)> = None;
        for &task in tasks {
            let t_src = sys.exec_time(self.src_it, task);
            let src_new_exec = if self.src_len == 1 && sys.overhead == 0.0 {
                0.0
            } else {
                sys.overhead + self.src_work - t_src
            };
            for to in 0..arena.n_vms() {
                if to == self.from {
                    continue;
                }
                let dst_it = arena.it_at(to);
                let dst_new_exec = sys.overhead + arena.work_at(to) + sys.exec_time(dst_it, task);
                // Strict improvement on both ends: the pair's new max must
                // drop below the current makespan.
                let pair_max = src_new_exec.max(dst_new_exec);
                if pair_max >= self.makespan - 1e-9 {
                    continue;
                }
                // Cost cap: total billed cost after the move stays bounded.
                let src_new_cost =
                    billed_cost(src_new_exec, sys.rate(self.src_it), sys.hour, sys.billing);
                let dst_new_cost =
                    billed_cost(dst_new_exec, sys.rate(dst_it), sys.hour, sys.billing);
                let new_total = self.total_cost + (src_new_cost - self.src_cost)
                    + (dst_new_cost - arena.cost_at(sys, to));
                if new_total > self.cost_cap + 1e-9 {
                    continue;
                }
                if best.as_ref().is_none_or(|(b, _, _, _)| pair_max < *b) {
                    best = Some((pair_max, to, task, new_total));
                }
            }
        }
        best
    }
}

/// Find the single best (source, receiver, task) move off the current
/// makespan VM, or `None` if no move strictly helps.  `execs` carries the
/// caller-maintained per-VM execution times.  Returns the plan's total
/// cost after the move as the fourth element.
fn best_rebalancing_move(
    sys: &System,
    arena: &PlanArena,
    execs: &[f64],
    total_cost: f64,
    cost_cap: f64,
    threads: usize,
) -> Option<(usize, usize, TaskId, f64)> {
    if arena.n_vms() < 2 {
        return None;
    }
    let (from, &makespan) = execs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1))?;
    if arena.is_empty_at(from) {
        return None;
    }
    let ctx = ScanCtx {
        sys,
        arena,
        from,
        makespan,
        src_it: arena.it_at(from),
        src_work: arena.work_at(from),
        src_len: arena.len_at(from),
        src_cost: arena.cost_at(sys, from),
        total_cost,
        cost_cap,
    };
    let tasks = arena.tasks_at(from);
    let n = tasks.len();
    let threads = resolve_threads(threads).min(n.max(1));
    let best = if threads <= 1 || n < MIN_CHUNKED_TASKS {
        ctx.scan(tasks)
    } else {
        // Contiguous task ranges, each scanned with the sequential rule;
        // merged *in range order* with a strict `<` so the earliest
        // occurrence of the global minimum wins — exactly the sequential
        // first-minimum outcome at any chunking.
        let per = n.div_ceil(threads * 4).max(1);
        let chunks = n.div_ceil(per);
        let chunk_best = parallel_map(threads, chunks, |ci| {
            let lo = ci * per;
            let hi = (lo + per).min(n);
            ctx.scan(&tasks[lo..hi])
        });
        let mut merged: Option<(f64, usize, TaskId, f64)> = None;
        for cand in chunk_best.into_iter().flatten() {
            if merged.as_ref().is_none_or(|(b, _, _, _)| cand.0 < *b) {
                merged = Some(cand);
            }
        }
        merged
    };
    best.map(|(_, to, task, new_cost)| (from, to, task, new_cost))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    fn sys_uniform(n_tasks: usize) -> System {
        SystemBuilder::new()
            .app("a", vec![1.0; n_tasks])
            .instance_type("x", 5.0, vec![100.0])
            .instance_type("y", 5.000001, vec![100.0])
            .build()
            .unwrap()
    }

    #[test]
    fn evens_out_two_vms() {
        let s = sys_uniform(8);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let before = p.exec(&s);
        let moves = balance(&s, &mut p, f64::INFINITY);
        assert!(moves > 0);
        assert!(p.exec(&s) < before);
        assert_eq!(p.vms[0].len(), 4);
        assert_eq!(p.vms[1].len(), 4);
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn cost_cap_blocks_spreading_to_unpaid_vm() {
        let s = sys_uniform(8);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id); // 800s -> cost 5
        }
        // Cap at the current cost: loading the empty VM costs ~5 more.
        assert_eq!(balance(&s, &mut p, 5.0), 0);
        assert_eq!(p.vms[1].len(), 0);
        // With cap 10.000001 the spread is allowed.
        assert!(balance(&s, &mut p, 10.01) > 0);
    }

    #[test]
    fn never_increases_makespan_and_respects_cap() {
        let s = SystemBuilder::new()
            .app("a", vec![3.0, 1.0, 4.0, 1.0, 5.0, 2.0])
            .app("b", vec![2.0, 2.0, 2.0])
            .instance_type("small", 5.0, vec![200.0, 300.0])
            .instance_type("cpu", 10.0, vec![100.0, 150.0])
            .overhead(30.0)
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let before = p.score(&s);
        let cap = before.cost + 20.0;
        balance(&s, &mut p, cap);
        let after = p.score(&s);
        assert!(after.makespan <= before.makespan + 1e-9);
        assert!(after.cost <= cap + 1e-9);
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn incremental_execs_stay_in_sync_with_fresh_recomputation() {
        // Run a multi-move balance and verify the plan it converges to is
        // a fixed point: re-running with freshly collected exec times
        // finds no further move.
        let s = SystemBuilder::new()
            .app("a", vec![3.0, 1.0, 4.0, 1.0, 5.0, 2.0, 6.0, 1.0])
            .app("b", vec![2.0, 2.0, 2.0, 3.0])
            .instance_type("small", 5.0, vec![200.0, 300.0])
            .instance_type("cpu", 10.0, vec![100.0, 150.0])
            .overhead(30.0)
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let moves = balance(&s, &mut p, f64::INFINITY);
        assert!(moves > 1, "scenario must exercise multiple iterations");
        assert_eq!(balance(&s, &mut p, f64::INFINITY), 0, "must converge to a fixed point");
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn single_vm_is_noop() {
        let s = sys_uniform(3);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        assert_eq!(balance(&s, &mut p, f64::INFINITY), 0);
    }

    #[test]
    fn balanced_input_is_fixed_point() {
        let s = sys_uniform(4);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        let v1 = p.add_vm(&s, InstanceTypeId(1));
        p.vms[v0].push_task(&s, TaskId(0));
        p.vms[v0].push_task(&s, TaskId(1));
        p.vms[v1].push_task(&s, TaskId(2));
        p.vms[v1].push_task(&s, TaskId(3));
        assert_eq!(balance(&s, &mut p, f64::INFINITY), 0);
    }

    #[test]
    fn threaded_move_search_matches_sequential_bit_for_bit() {
        // Enough tasks on the makespan VM to cross MIN_CHUNKED_TASKS so
        // the chunked path actually runs.
        let s = SystemBuilder::new()
            .app("a", (1..=30).map(|k| 1.0 + (k % 7) as f64 * 0.5).collect())
            .app("b", (1..=10).map(|k| 2.0 + (k % 3) as f64).collect())
            .instance_type("small", 5.0, vec![200.0, 300.0])
            .instance_type("cpu", 10.0, vec![100.0, 150.0])
            .overhead(30.0)
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let mut seq = PlanArena::from_plan(&s, &p);
        let seq_moves = balance_arena(&s, &mut seq, f64::INFINITY);
        assert!(seq_moves > 0);
        for threads in [2usize, 4, 0] {
            let mut par = PlanArena::from_plan(&s, &p);
            let par_moves = balance_arena_threaded(&s, &mut par, f64::INFINITY, threads);
            assert_eq!(seq_moves, par_moves, "threads={threads}");
            let (a, b) = (seq.to_plan(), par.to_plan());
            assert_eq!(a.vms.len(), b.vms.len());
            for (va, vb) in a.vms.iter().zip(&b.vms) {
                assert_eq!(va.it, vb.it);
                assert_eq!(va.tasks(), vb.tasks(), "threads={threads}");
            }
        }
    }

    #[test]
    fn arena_level_entry_balances_in_place() {
        let s = sys_uniform(8);
        let mut p = Plan::new();
        let v0 = p.add_vm(&s, InstanceTypeId(0));
        p.add_vm(&s, InstanceTypeId(1));
        for t in s.tasks() {
            p.vms[v0].push_task(&s, t.id);
        }
        let mut arena = PlanArena::from_plan(&s, &p);
        let moves = balance_arena(&s, &mut arena, f64::INFINITY);
        assert!(moves > 0);
        assert_eq!(arena.len_at(0), 4);
        assert_eq!(arena.len_at(1), 4);
        assert!(arena.to_plan().validate_partition(&s).is_ok());
    }
}
