//! Future-work extension (Sec. VI): **dynamic rescheduling** — re-planning
//! mid-execution "to handle any unexpected issues during runtime".
//!
//! Given the set of tasks that still have to run (pending on live VMs,
//! stranded on failed VMs, or not yet dispatched) and the money left, a
//! fresh plan for exactly that residual workload is computed by extracting
//! a *sub-system* (same catalogue/overhead, residual tasks only), running
//! Algorithm 1 on it, and mapping task ids back to the parent system.
//! The cloud simulator's failure-injection path drives this module (see
//! `cloudsim::campaign` and the `noisy_cloud` example).

use std::collections::HashMap;

use super::find::{FindReport, PlannerConfig};
use super::policy::{BudgetHeuristic, Policy, SolveOutcome, SolveRequest};
use crate::model::{Plan, System, TaskId};

/// A sub-problem over a subset of the parent's tasks.
pub struct SubProblem {
    /// The derived system (ids renumbered, catalogue shared).
    pub sys: System,
    /// `sub task id -> parent task id`.
    pub back: Vec<TaskId>,
}

/// Build the residual sub-problem for `remaining` (parent task ids).
///
/// Panics if `remaining` is empty — callers should short-circuit instead.
pub fn subproblem(parent: &System, remaining: &[TaskId]) -> SubProblem {
    assert!(!remaining.is_empty(), "subproblem over zero tasks");
    // Group the residual tasks by application, preserving order.
    let mut per_app: Vec<Vec<(TaskId, f64)>> = vec![Vec::new(); parent.n_apps()];
    for &tid in remaining {
        let t = parent.task(tid);
        per_app[t.app.index()].push((tid, t.size));
    }
    let mut b = crate::model::SystemBuilder::new()
        .overhead(parent.overhead)
        .hour(parent.hour)
        .billing(parent.billing);
    // Keep *all* apps (even now-empty ones) so AppId indices — and hence
    // the performance matrix columns — line up with the parent.
    let mut back = Vec::with_capacity(remaining.len());
    for (ai, app) in parent.apps.iter().enumerate() {
        let sizes: Vec<f64> = per_app[ai].iter().map(|(_, s)| *s).collect();
        for (tid, _) in &per_app[ai] {
            back.push(*tid);
        }
        b = b.app(&app.name, sizes);
    }
    for it in &parent.instance_types {
        b = b.instance_type(&it.name, it.cost_per_hour, parent.perf.row(it.id).to_vec());
    }
    let sys = b.build().expect("subproblem inherits a valid parent");
    // `back` above was built app-major in the same order SystemBuilder
    // flattens tasks, so sub TaskId(i) maps to back[i].
    SubProblem { sys, back }
}

/// Re-plan the residual workload with any [`Policy`]: build the
/// sub-problem, solve it, and translate the outcome's plan back to
/// **parent** task ids.
pub fn replan_policy(
    parent: &System,
    remaining: &[TaskId],
    policy: &dyn Policy,
    req: &SolveRequest,
) -> SolveOutcome {
    let sub = subproblem(parent, remaining);
    let mut outcome = policy.solve(&sub.sys, req);

    // Translate the plan back to parent ids.
    let mut parent_plan = Plan::new();
    for vm in &outcome.plan.vms {
        let idx = parent_plan.add_vm(parent, vm.it);
        for &sub_tid in vm.tasks() {
            parent_plan.vms[idx].push_task(parent, sub.back[sub_tid.index()]);
        }
    }
    outcome.plan = parent_plan;
    outcome
}

/// Re-plan the residual workload with the budget heuristic (legacy shim
/// over [`replan_policy`]); the report's plan is in **parent** task ids.
pub fn replan(
    parent: &System,
    remaining: &[TaskId],
    budget_left: f64,
    config: PlannerConfig,
) -> (Plan, FindReport) {
    let req = SolveRequest::new(budget_left).with_planner(config);
    let outcome = replan_policy(parent, remaining, &BudgetHeuristic, &req);
    let report = outcome.to_find_report();
    (outcome.plan, report)
}

/// Validate that `plan` covers exactly `remaining` (the dynamic analogue
/// of eq. 3/4, which `Plan::validate_partition` can't check because the
/// parent system has more tasks).
pub fn validate_residual(plan: &Plan, remaining: &[TaskId]) -> Result<(), String> {
    let mut want: HashMap<TaskId, bool> = remaining.iter().map(|t| (*t, false)).collect();
    for vm in &plan.vms {
        for t in vm.tasks() {
            match want.get_mut(t) {
                None => return Err(format!("task {} not in residual set", t.0)),
                Some(seen @ false) => *seen = true,
                Some(_) => return Err(format!("task {} assigned twice", t.0)),
            }
        }
    }
    if let Some((t, _)) = want.iter().find(|(_, seen)| !**seen) {
        return Err(format!("residual task {} unassigned", t.0));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn subproblem_preserves_catalogue_and_sizes() {
        let sys = table1_system(30.0);
        let remaining: Vec<TaskId> = sys.tasks().iter().step_by(3).map(|t| t.id).collect();
        let sub = subproblem(&sys, &remaining);
        assert_eq!(sub.sys.n_types(), 4);
        assert_eq!(sub.sys.n_apps(), 3);
        assert_eq!(sub.sys.tasks().len(), remaining.len());
        assert_eq!(sub.sys.overhead, 30.0);
        for (i, t) in sub.sys.tasks().iter().enumerate() {
            let parent_task = sys.task(sub.back[i]);
            assert_eq!(t.size, parent_task.size);
            assert_eq!(t.app, parent_task.app);
        }
    }

    #[test]
    fn replan_policy_runs_any_registered_policy() {
        let sys = table1_system(0.0);
        let remaining: Vec<TaskId> =
            sys.tasks().iter().filter(|t| t.id.0 % 4 == 0).map(|t| t.id).collect();
        let req = SolveRequest::new(40.0);
        for policy in [
            &crate::scheduler::MaximiseParallelism as &dyn Policy,
            &crate::scheduler::MinimiseIndividual,
            &BudgetHeuristic,
        ] {
            let outcome = replan_policy(&sys, &remaining, policy, &req);
            assert!(
                validate_residual(&outcome.plan, &remaining).is_ok(),
                "{}: bad residual cover",
                policy.name()
            );
        }
    }

    #[test]
    fn replan_covers_residual_exactly() {
        let sys = table1_system(0.0);
        let remaining: Vec<TaskId> =
            sys.tasks().iter().filter(|t| t.id.0 % 5 == 0).map(|t| t.id).collect();
        let (plan, report) = replan(&sys, &remaining, 30.0, PlannerConfig::default());
        assert!(validate_residual(&plan, &remaining).is_ok());
        assert!(report.iterations >= 1);
    }

    #[test]
    fn validate_residual_catches_extra_and_missing() {
        let sys = table1_system(0.0);
        let remaining = vec![TaskId(0), TaskId(1)];
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, crate::model::InstanceTypeId(0));
        plan.vms[v].push_task(&sys, TaskId(0));
        assert!(validate_residual(&plan, &remaining).unwrap_err().contains("unassigned"));
        plan.vms[v].push_task(&sys, TaskId(7));
        assert!(validate_residual(&plan, &remaining).unwrap_err().contains("not in residual"));
    }
}
