//! Future-work extension (Sec. VI): deadline-constrained **cost
//! minimisation** — the dual of Algorithm 1.
//!
//! "For future work, we plan to further expand our heuristic algorithm to
//! take into account the execution deadline while minimising the cost."
//!
//! The cost/budget relation of FIND is monotone in practice: a larger
//! budget never yields a slower returned plan (more money buys at least
//! the same VMs).  We therefore bisect the smallest budget whose plan
//! meets the deadline, then return that plan.  Non-monotone blips from
//! the heuristic are absorbed by tracking the best (cheapest meeting the
//! deadline) plan seen during the search.
//!
//! **Parallel probes by speculative bisection.**  Bisection is
//! inherently sequential — each probe decides the next interval — so
//! naive fan-out would change the probe sequence and therefore the
//! result.  Instead, [`min_cost_for_deadline_ctl`] speculates: with `t`
//! worker threads it evaluates the next `d = ⌊log₂(t+1)⌋` *levels* of
//! the bisection decision tree (all `2^d − 1` candidate midpoints, heap
//! order) in one [`crate::util::parallel`] fan-out, then walks the tree
//! exactly as the sequential loop would, consuming the precomputed
//! probes.  The walked path — probe points, best-plan updates, reported
//! probe count — is bit-for-bit the sequential search at any thread
//! count; the off-path probes are discarded wall-clock speculation
//! (2× / 3× fewer rounds at 4 / 8 threads).  `threads <= 1` runs the
//! literal sequential loop.
//!
//! Cancellation: the planner's [`CancelToken`] is polled between
//! bisection rounds (and inside each FIND via the planner itself); a
//! cancelled search returns the best plan found so far.
//!
//! [`CancelToken`]: crate::util::CancelToken

use super::find::{FindReport, Planner};
use crate::model::System;
use crate::util::{parallel_map, resolve_threads};

/// Result of a deadline-constrained search.
#[derive(Debug, Clone)]
pub struct DeadlineReport {
    /// The cheapest plan found meeting the deadline, if any.
    pub report: Option<FindReport>,
    /// When the deadline is unreachable: the best plan at the full cap
    /// (already computed by the search — callers can report best-effort
    /// without planning again).
    pub best_effort: Option<FindReport>,
    /// The budget that produced `report`.
    pub budget: f64,
    /// Planner invocations consumed by the search *path* (identical at
    /// any thread count; speculative off-path probes are not counted).
    pub probes: usize,
}

/// Find (approximately) the cheapest plan with makespan `<= deadline`
/// seconds.  `budget_hi` caps the search (e.g. the user's absolute
/// spending limit); returns `report: None` when even `budget_hi` cannot
/// meet the deadline.
pub fn min_cost_for_deadline(sys: &System, deadline: f64, budget_hi: f64) -> DeadlineReport {
    min_cost_for_deadline_with(&Planner::new(sys), deadline, budget_hi)
}

/// [`min_cost_for_deadline`] probing through a caller-configured planner
/// (evaluator + phase toggles), so policy-level settings apply to every
/// bisection probe.  Sequential (one probe per round).
pub fn min_cost_for_deadline_with(
    planner: &Planner,
    deadline: f64,
    budget_hi: f64,
) -> DeadlineReport {
    min_cost_for_deadline_ctl(planner, deadline, budget_hi, 1)
}

/// Whether a probe result meets the deadline within the budget probed.
fn meets(r: &FindReport, deadline: f64) -> bool {
    r.feasible && r.score.makespan <= deadline + 1e-6
}

/// [`min_cost_for_deadline_with`] with the bisection probes speculated
/// across `threads` workers (0 = auto, 1 = sequential; see the module
/// docs).  The returned report — plan, budget, probe count — is
/// bit-identical at any thread count.
pub fn min_cost_for_deadline_ctl(
    planner: &Planner,
    deadline: f64,
    budget_hi: f64,
    threads: usize,
) -> DeadlineReport {
    let sys = planner.sys;
    let mut probes = 0usize;

    // Budget lower bound: one hour of the cheapest machine.  A cap below
    // that cannot buy any machine-hour — the budget is a hard spending
    // limit, so the search must not silently raise it.
    let mut lo = sys
        .instance_types
        .iter()
        .map(|it| it.cost_per_hour)
        .fold(f64::INFINITY, f64::min);
    if budget_hi + 1e-9 < lo {
        return DeadlineReport { report: None, best_effort: None, budget: budget_hi, probes };
    }
    let mut hi = budget_hi;

    // Check feasibility at the cap first.
    let top = planner.find(hi);
    probes += 1;
    if !meets(&top, deadline) {
        return DeadlineReport { report: None, best_effort: Some(top), budget: hi, probes };
    }
    let mut best = top;
    let mut best_budget = hi;

    // Levels of the bisection decision tree to speculate per round:
    // 2^d - 1 probes buy d guaranteed levels of progress.
    let t = resolve_threads(threads);
    let spec_depth = if t <= 1 { 1 } else { (usize::BITS - (t + 1).leading_zeros() - 1) as usize };

    // Bisect to cost granularity (budgets are money: 2 decimal places).
    while hi - lo > 0.01 {
        if planner.cancel.is_cancelled() {
            break; // return the cheapest deadline-meeting plan so far
        }
        if spec_depth <= 1 {
            // The literal sequential loop (and the parity baseline).
            let mid = (lo + hi) / 2.0;
            let r = planner.find(mid);
            probes += 1;
            if meets(&r, deadline) {
                if r.score.cost < best.score.cost - 1e-9 {
                    best = r;
                    best_budget = mid;
                }
                hi = mid;
            } else {
                lo = mid;
            }
            continue;
        }

        // Speculative round: materialise the next `spec_depth` levels of
        // the decision tree in heap order.  Node j covers an interval;
        // its midpoint is the probe the sequential loop would issue on
        // the path reaching it, computed with the exact same floats.
        let n_nodes = (1usize << spec_depth) - 1;
        let mut intervals: Vec<(f64, f64)> = Vec::with_capacity(n_nodes);
        intervals.push((lo, hi));
        let mut j = 0;
        while j < n_nodes {
            let (nlo, nhi) = intervals[j];
            let mid = (nlo + nhi) / 2.0;
            if 2 * j + 2 < n_nodes {
                intervals.push((nlo, mid));
                intervals.push((mid, nhi));
            }
            j += 1;
        }
        let mut reports: Vec<Option<FindReport>> =
            parallel_map(threads, n_nodes, |j| {
                let (nlo, nhi) = intervals[j];
                Some(planner.find((nlo + nhi) / 2.0))
            });

        // Walk the precomputed tree exactly as the sequential loop
        // would, stopping at convergence (unused speculation is waste,
        // never a behaviour change).
        let mut j = 0usize;
        for _ in 0..spec_depth {
            if hi - lo <= 0.01 {
                break;
            }
            let mid = (lo + hi) / 2.0;
            let r = reports[j].take().expect("each tree node visited at most once");
            probes += 1;
            if meets(&r, deadline) {
                if r.score.cost < best.score.cost - 1e-9 {
                    best = r;
                    best_budget = mid;
                }
                hi = mid;
                j = 2 * j + 1;
            } else {
                lo = mid;
                j = 2 * j + 2;
            }
        }
    }
    DeadlineReport { report: Some(best), best_effort: None, budget: best_budget, probes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn loose_deadline_costs_less_than_tight() {
        let sys = table1_system(0.0);
        let loose = min_cost_for_deadline(&sys, 4.0 * 3600.0, 200.0);
        let tight = min_cost_for_deadline(&sys, 1.0 * 3600.0, 200.0);
        let (Some(l), Some(t)) = (&loose.report, &tight.report) else {
            panic!("both deadlines should be satisfiable at budget 200");
        };
        assert!(l.score.makespan <= 4.0 * 3600.0 + 1e-6);
        assert!(t.score.makespan <= 1.0 * 3600.0 + 1e-6);
        assert!(l.score.cost <= t.score.cost + 1e-9, "loose {} > tight {}", l.score.cost, t.score.cost);
    }

    #[test]
    fn impossible_deadline_returns_none() {
        let sys = table1_system(0.0);
        // 10 seconds is impossible: smallest single task needs >= 9s and
        // boot + any real split cannot reach it for 750 tasks at budget 60.
        let r = min_cost_for_deadline(&sys, 10.0, 60.0);
        assert!(r.report.is_none());
        assert!(r.probes >= 1);
    }

    #[test]
    fn returned_plan_is_valid() {
        let sys = table1_system(0.0);
        let r = min_cost_for_deadline(&sys, 2.0 * 3600.0, 150.0);
        let rep = r.report.expect("satisfiable");
        assert!(rep.plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn speculative_probes_bit_identical_at_any_thread_count() {
        let sys = table1_system(0.0);
        let planner = Planner::new(&sys);
        for &(deadline, cap) in &[(2.0 * 3600.0, 150.0), (1.0 * 3600.0, 200.0), (10.0, 60.0)] {
            let seq = min_cost_for_deadline_ctl(&planner, deadline, cap, 1);
            for threads in [2usize, 4, 8] {
                let par = min_cost_for_deadline_ctl(&planner, deadline, cap, threads);
                assert_eq!(par.probes, seq.probes, "threads {threads}: probe path diverged");
                assert_eq!(par.budget.to_bits(), seq.budget.to_bits(), "threads {threads}");
                match (&par.report, &seq.report) {
                    (None, None) => {}
                    (Some(a), Some(b)) => {
                        assert_eq!(a.score.cost.to_bits(), b.score.cost.to_bits());
                        assert_eq!(a.score.makespan.to_bits(), b.score.makespan.to_bits());
                        assert_eq!(a.plan.n_vms(), b.plan.n_vms());
                        for (x, y) in a.plan.vms.iter().zip(&b.plan.vms) {
                            assert_eq!(x.it, y.it);
                            assert_eq!(x.tasks(), y.tasks());
                        }
                    }
                    _ => panic!("threads {threads}: feasibility verdict diverged"),
                }
            }
        }
    }

    #[test]
    fn cancelled_search_stops_with_best_so_far() {
        let sys = table1_system(0.0);
        let cancel = crate::util::CancelToken::new();
        let planner = Planner::new(&sys).with_cancel(cancel.clone());
        cancel.cancel();
        // Cancelled after the cap probe: exactly one probe is spent, and
        // the search still returns that probe's plan (as the result or
        // as best-effort, depending on whether it met the deadline).
        let r = min_cost_for_deadline_ctl(&planner, 2.0 * 3600.0, 150.0, 1);
        assert_eq!(r.probes, 1);
        assert!(r.report.is_some() || r.best_effort.is_some());
    }
}
