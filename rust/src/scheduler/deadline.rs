//! Future-work extension (Sec. VI): deadline-constrained **cost
//! minimisation** — the dual of Algorithm 1.
//!
//! "For future work, we plan to further expand our heuristic algorithm to
//! take into account the execution deadline while minimising the cost."
//!
//! The cost/budget relation of FIND is monotone in practice: a larger
//! budget never yields a slower returned plan (more money buys at least
//! the same VMs).  We therefore bisect the smallest budget whose plan
//! meets the deadline, then return that plan.  Non-monotone blips from
//! the heuristic are absorbed by tracking the best (cheapest meeting the
//! deadline) plan seen during the search.

use super::find::{FindReport, Planner};
use crate::model::System;

/// Result of a deadline-constrained search.
#[derive(Debug, Clone)]
pub struct DeadlineReport {
    /// The cheapest plan found meeting the deadline, if any.
    pub report: Option<FindReport>,
    /// When the deadline is unreachable: the best plan at the full cap
    /// (already computed by the search — callers can report best-effort
    /// without planning again).
    pub best_effort: Option<FindReport>,
    /// The budget that produced `report`.
    pub budget: f64,
    /// Planner invocations spent in the bisection.
    pub probes: usize,
}

/// Find (approximately) the cheapest plan with makespan `<= deadline`
/// seconds.  `budget_hi` caps the search (e.g. the user's absolute
/// spending limit); returns `report: None` when even `budget_hi` cannot
/// meet the deadline.
pub fn min_cost_for_deadline(sys: &System, deadline: f64, budget_hi: f64) -> DeadlineReport {
    min_cost_for_deadline_with(&Planner::new(sys), deadline, budget_hi)
}

/// [`min_cost_for_deadline`] probing through a caller-configured planner
/// (evaluator + phase toggles), so policy-level settings apply to every
/// bisection probe.
pub fn min_cost_for_deadline_with(
    planner: &Planner,
    deadline: f64,
    budget_hi: f64,
) -> DeadlineReport {
    let sys = planner.sys;
    let mut probes = 0usize;

    // Budget lower bound: one hour of the cheapest machine.  A cap below
    // that cannot buy any machine-hour — the budget is a hard spending
    // limit, so the search must not silently raise it.
    let mut lo = sys
        .instance_types
        .iter()
        .map(|it| it.cost_per_hour)
        .fold(f64::INFINITY, f64::min);
    if budget_hi + 1e-9 < lo {
        return DeadlineReport { report: None, best_effort: None, budget: budget_hi, probes };
    }
    let mut hi = budget_hi;

    // Check feasibility at the cap first.
    let top = planner.find(hi);
    probes += 1;
    if !(top.feasible && top.score.makespan <= deadline + 1e-6) {
        return DeadlineReport { report: None, best_effort: Some(top), budget: hi, probes };
    }
    let mut best = top;
    let mut best_budget = hi;

    // Bisect to cost granularity (budgets are money: 2 decimal places).
    while hi - lo > 0.01 {
        let mid = (lo + hi) / 2.0;
        let r = planner.find(mid);
        probes += 1;
        if r.feasible && r.score.makespan <= deadline + 1e-6 {
            if r.score.cost < best.score.cost - 1e-9 {
                best = r;
                best_budget = mid;
            }
            hi = mid;
        } else {
            lo = mid;
        }
    }
    DeadlineReport { report: Some(best), best_effort: None, budget: best_budget, probes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn loose_deadline_costs_less_than_tight() {
        let sys = table1_system(0.0);
        let loose = min_cost_for_deadline(&sys, 4.0 * 3600.0, 200.0);
        let tight = min_cost_for_deadline(&sys, 1.0 * 3600.0, 200.0);
        let (Some(l), Some(t)) = (&loose.report, &tight.report) else {
            panic!("both deadlines should be satisfiable at budget 200");
        };
        assert!(l.score.makespan <= 4.0 * 3600.0 + 1e-6);
        assert!(t.score.makespan <= 1.0 * 3600.0 + 1e-6);
        assert!(l.score.cost <= t.score.cost + 1e-9, "loose {} > tight {}", l.score.cost, t.score.cost);
    }

    #[test]
    fn impossible_deadline_returns_none() {
        let sys = table1_system(0.0);
        // 10 seconds is impossible: smallest single task needs >= 9s and
        // boot + any real split cannot reach it for 750 tasks at budget 60.
        let r = min_cost_for_deadline(&sys, 10.0, 60.0);
        assert!(r.report.is_none());
        assert!(r.probes >= 1);
    }

    #[test]
    fn returned_plan_is_valid() {
        let sys = table1_system(0.0);
        let r = min_cost_for_deadline(&sys, 2.0 * 3600.0, 150.0);
        let rep = r.report.expect("satisfiable");
        assert!(rep.plan.validate_partition(&sys).is_ok());
    }
}
