//! Sec. IV-E `ADD`: spend remaining budget on extra VMs.
//!
//! After the budget holds, leftover money buys additional concurrency.
//! Each new VM's price is estimated under the paper's one-hour assumption
//! ("by assuming that each of them would not be executed for more than one
//! hour"), and the chosen type is the best-performing affordable one —
//! minimal `exec_{it,T}` (total serial time over all tasks) with the price
//! as tie-break.  VMs are added until no type is affordable.
//!
//! The MI baseline (Sec. V-A1) is exactly this function run with the full
//! budget on an empty plan.

use crate::model::{InstanceTypeId, Plan, System};

/// Add as many VMs as `remaining_budget` affords; returns the indices of
/// the VMs created (in creation order).
pub fn add_vms(sys: &System, plan: &mut Plan, remaining_budget: f64) -> Vec<usize> {
    let mut remaining = remaining_budget;
    let mut created = Vec::new();
    while let Some(it) = pick_type(sys, remaining) {
        created.push(plan.add_vm(sys, it));
        remaining -= sys.rate(it);
    }
    created
}

/// The cheapest instance type with the lowest execution time for all
/// tasks, among those affordable within `budget` (one-hour assumption).
pub fn pick_type(sys: &System, budget: f64) -> Option<InstanceTypeId> {
    sys.instance_types
        .iter()
        .filter(|it| it.cost_per_hour <= budget + 1e-9)
        .min_by(|a, b| {
            sys.total_exec_time(a.id)
                .total_cmp(&sys.total_exec_time(b.id))
                .then(a.cost_per_hour.total_cmp(&b.cost_per_hour))
        })
        .map(|it| it.id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn picks_best_average_performer() {
        let sys = table1_system(0.0);
        // Total exec: it1 = 49500, it2 = 27000, it3 = 25500, it4 = 23250.
        assert_eq!(pick_type(&sys, 100.0), Some(InstanceTypeId(3)));
        // Below 10 only it_1 is affordable.
        assert_eq!(pick_type(&sys, 7.0), Some(InstanceTypeId(0)));
        assert_eq!(pick_type(&sys, 1.0), None);
    }

    #[test]
    fn mi_shape_it4_then_it1_with_remainder() {
        let sys = table1_system(0.0);
        let mut plan = Plan::new();
        let created = add_vms(&sys, &mut plan, 45.0);
        // 4 x it_4 (40) then 5 remaining buys one it_1.
        assert_eq!(created.len(), 5);
        let mix = plan.vm_mix(&sys);
        assert_eq!(mix, vec![1, 0, 0, 4]);
    }

    #[test]
    fn zero_budget_adds_nothing() {
        let sys = table1_system(0.0);
        let mut plan = Plan::new();
        assert!(add_vms(&sys, &mut plan, 0.0).is_empty());
        assert!(add_vms(&sys, &mut plan, 4.999).is_empty());
    }

    #[test]
    fn exact_price_is_affordable() {
        let sys = table1_system(0.0);
        let mut plan = Plan::new();
        let created = add_vms(&sys, &mut plan, 10.0);
        assert_eq!(created.len(), 1);
        assert_eq!(plan.vms[0].it, InstanceTypeId(3));
    }
}
