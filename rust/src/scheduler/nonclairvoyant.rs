//! Future-work extension (Sec. VI): **non-clairvoyant** scheduling —
//! task execution times are unknown up front.
//!
//! Two pieces:
//!
//! 1. *Planning under estimated sizes.*  The scheduler is run on a
//!    surrogate system in which every task of an application carries that
//!    application's estimated mean size (optionally bootstrapped from a
//!    sampled fraction, mirroring the paper's "test runs" suggestion).
//!    Provisioning decisions (how many VMs of which types) transfer to
//!    the real workload; only the task-to-VM pinning is discarded.
//! 2. *Online dispatch.*  At run time tasks are pulled from per-app FIFO
//!    queues by whichever VM goes idle first (self-scheduling /
//!    work-stealing), which is the classic non-clairvoyant BoT policy.
//!    The cloud simulator implements the clock; [`OnlineDispatcher`]
//!    implements the policy.

use std::collections::VecDeque;

use crate::model::{AppId, InstanceTypeId, System, TaskId};
use crate::util::Rng;

/// Build the surrogate system: identical catalogue, every task size
/// replaced by its app's estimate.  `sample_frac in (0, 1]` controls how
/// many real sizes the estimator may look at (1.0 = oracle mean).
pub fn surrogate_system(sys: &System, sample_frac: f64, rng: &mut Rng) -> System {
    assert!(sample_frac > 0.0 && sample_frac <= 1.0);
    let mut b = crate::model::SystemBuilder::new()
        .overhead(sys.overhead)
        .hour(sys.hour)
        .billing(sys.billing);
    for app in &sys.apps {
        let n = app.len();
        let k = ((n as f64 * sample_frac).ceil() as usize).clamp(1, n);
        // Sample k sizes without replacement.
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let mean: f64 = idx[..k].iter().map(|&i| app.task_sizes[i]).sum::<f64>() / k as f64;
        b = b.app(&app.name, vec![mean.max(1e-6); n]);
    }
    for it in &sys.instance_types {
        b = b.instance_type(&it.name, it.cost_per_hour, sys.perf.row(it.id).to_vec());
    }
    b.build().expect("surrogate inherits a valid parent")
}

/// Online self-scheduling dispatcher: per-application FIFO queues; an idle
/// VM takes the next task of the application its instance type executes
/// fastest among the non-empty queues.
#[derive(Debug, Clone)]
pub struct OnlineDispatcher {
    queues: Vec<VecDeque<TaskId>>,
}

impl OnlineDispatcher {
    /// Queue every task of the system, in id order.
    pub fn new(sys: &System) -> Self {
        let mut queues = vec![VecDeque::new(); sys.n_apps()];
        for t in sys.tasks() {
            queues[t.app.index()].push_back(t.id);
        }
        Self { queues }
    }

    /// Queue an explicit task set (e.g. a residual workload).
    pub fn with_tasks(sys: &System, tasks: &[TaskId]) -> Self {
        let mut queues = vec![VecDeque::new(); sys.n_apps()];
        for &tid in tasks {
            queues[sys.task(tid).app.index()].push_back(tid);
        }
        Self { queues }
    }

    pub fn remaining(&self) -> usize {
        self.queues.iter().map(VecDeque::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Return a task back (e.g. its VM failed mid-flight).
    pub fn requeue(&mut self, sys: &System, task: TaskId) {
        self.queues[sys.task(task).app.index()].push_front(task);
    }

    /// Next task for an idle VM of type `it`: the head of the non-empty
    /// queue whose application this type runs fastest (per unit size).
    pub fn next_for(&mut self, sys: &System, it: InstanceTypeId) -> Option<TaskId> {
        let mut best: Option<(f64, usize)> = None;
        for (ai, q) in self.queues.iter().enumerate() {
            if q.is_empty() {
                continue;
            }
            let speed = sys.perf.get(it, AppId(ai as u16));
            if best.is_none_or(|(s, _)| speed < s) {
                best = Some((speed, ai));
            }
        }
        best.and_then(|(_, ai)| self.queues[ai].pop_front())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn oracle_surrogate_preserves_total_size() {
        let sys = table1_system(0.0);
        let mut rng = Rng::new(1);
        let sur = surrogate_system(&sys, 1.0, &mut rng);
        for (a, b) in sys.apps.iter().zip(&sur.apps) {
            assert!((a.total_size() - b.total_size()).abs() < 1e-6);
            assert_eq!(a.len(), b.len());
        }
    }

    #[test]
    fn sampled_surrogate_is_close() {
        let sys = table1_system(0.0);
        let mut rng = Rng::new(2);
        let sur = surrogate_system(&sys, 0.2, &mut rng);
        for (a, b) in sys.apps.iter().zip(&sur.apps) {
            let rel = (a.total_size() - b.total_size()).abs() / a.total_size();
            assert!(rel < 0.25, "estimate off by {rel}");
        }
    }

    #[test]
    fn dispatcher_prefers_fast_queue_and_drains() {
        let sys = table1_system(0.0);
        let mut d = OnlineDispatcher::new(&sys);
        assert_eq!(d.remaining(), 750);
        // it_4 runs A2 fastest (9 s/u) -> must draw from A2's queue first.
        let t = d.next_for(&sys, InstanceTypeId(3)).unwrap();
        assert_eq!(sys.task(t).app, AppId(1));
        // it_3 runs A3 fastest (9 s/u).
        let t = d.next_for(&sys, InstanceTypeId(2)).unwrap();
        assert_eq!(sys.task(t).app, AppId(2));
        // Drain fully.
        let mut n = d.remaining();
        while let Some(_t) = d.next_for(&sys, InstanceTypeId(0)) {
            n -= 1;
        }
        assert_eq!(n, 0);
        assert!(d.is_empty());
    }

    #[test]
    fn requeue_puts_task_back_at_front() {
        let sys = table1_system(0.0);
        let mut d = OnlineDispatcher::with_tasks(&sys, &[TaskId(0), TaskId(1)]);
        let t = d.next_for(&sys, InstanceTypeId(0)).unwrap();
        d.requeue(&sys, t);
        assert_eq!(d.remaining(), 2);
        assert_eq!(d.next_for(&sys, InstanceTypeId(0)).unwrap(), t);
    }
}
