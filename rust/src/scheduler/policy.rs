//! The unified solver API: one entry point for every scheduling policy.
//!
//! The paper contributes one planner among several competitors (the
//! Section IV budget heuristic vs the Section V MI/MP baselines, plus the
//! Section VI deadline / dynamic / non-clairvoyant extensions), and the
//! companion papers (arXiv:1507.05470, arXiv:1506.00590) add more policy
//! variants.  Historically each had its own ad-hoc entry point
//! (`Planner::find`, `find_multistart`, `minimise_individual`, ...), which
//! forced the coordinator, the cloud simulator, the examples and the
//! benches to hand-wire every policy separately.
//!
//! This module is the single uniform surface instead:
//!
//! * [`Policy`] — `solve(&self, sys, req) -> SolveOutcome`, object-safe so
//!   registries, campaign specs and wire handlers can hold `dyn Policy`;
//! * [`SolveRequest`] — a builder carrying the budget, an optional
//!   deadline, the evaluator handle, a seed and the per-policy tuning
//!   knobs (planner phase toggles, restart count, sample fraction, ...);
//! * [`SolveOutcome`] — the unified return shape: plan, score, budget
//!   feasibility, iteration/probe counts and the budget that produced the
//!   plan;
//! * [`PolicyRegistry`] — resolves string names (`"budget-heuristic"`,
//!   `"mi"`, `"mp"`, `"multistart"`, `"deadline"`, `"dynamic"`,
//!   `"nonclairvoyant"`) to policies, so adding a future policy is one
//!   `impl Policy` plus one registry line.
//!
//! The legacy entry points remain as thin wrappers over the same
//! underlying phase implementations, so existing code keeps compiling;
//! new code should go through this API.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use crate::eval::{NativeEvaluator, PlanEvaluator};
use crate::model::{Plan, PlanScore, System, TaskId};
use crate::util::{CancelToken, Rng};

use super::baselines::{maximise_parallelism, minimise_individual};
use super::deadline::min_cost_for_deadline_ctl;
use super::find::{FindReport, Planner, PlannerConfig};
use super::multistart::{find_multistart, MultiStartConfig};
use super::nonclairvoyant::surrogate_system;
use super::{assign, balance};

/// Map legacy / spelling-variant policy names onto the canonical registry
/// names (`"heuristic"` was the coordinator's historical name for the
/// paper's budget heuristic).
pub fn canonical_name(name: &str) -> &str {
    match name {
        "heuristic" | "find" | "algorithm1" => "budget-heuristic",
        "non-clairvoyant" => "nonclairvoyant",
        "multi-start" => "multistart",
        "minimise-individual" | "minimize-individual" => "mi",
        "maximise-parallelism" | "maximize-parallelism" => "mp",
        other => other,
    }
}

/// Inverse of [`canonical_name`] for the one renamed policy: legacy wire
/// fields (`"approach"`) keep the historical `"heuristic"` spelling so
/// pre-registry clients keep matching.
pub fn legacy_name(name: &str) -> &str {
    if name == "budget-heuristic" {
        "heuristic"
    } else {
        name
    }
}

/// A structured solve request: what to optimise, under which constraints,
/// scored through which evaluator, with which policy-specific knobs.
///
/// Knobs irrelevant to a policy are simply ignored by it (e.g. `n_starts`
/// only matters to `"multistart"`), so one request can be replayed across
/// the whole registry.
#[derive(Clone)]
pub struct SolveRequest<'a> {
    /// The budget `B` of eq. 9 (for `"deadline"` this is the spending cap
    /// the bisection may not exceed).
    pub budget: f64,
    /// Completion deadline in seconds (used by `"deadline"`; `None` means
    /// unconstrained, i.e. pure cost minimisation).
    pub deadline: Option<f64>,
    /// Seed for stochastic policies (`"multistart"` restarts,
    /// `"nonclairvoyant"` size sampling).
    pub seed: u64,
    /// Phase toggles + iteration cap for Algorithm 1 (all policies built
    /// on FIND honour this).
    pub planner: PlannerConfig,
    /// Restart count for `"multistart"`.
    pub n_starts: usize,
    /// Perf-matrix jitter for `"multistart"` restarts.
    pub perf_jitter: f64,
    /// Fraction of task sizes the `"nonclairvoyant"` estimator may sample
    /// (`1.0` = oracle mean).
    pub sample_frac: f64,
    /// Residual task set for `"dynamic"` re-planning (`None` or empty =
    /// the full workload).
    pub remaining: Option<Vec<TaskId>>,
    /// Worker threads for parallelisable policies: 1 = sequential
    /// (default), 0 = auto-detect.  `"multistart"` restarts and
    /// `"deadline"` bisection probes fan out over
    /// [`crate::util::parallel`]; single-solve policies
    /// (`"budget-heuristic"`, `"dynamic"`, `"nonclairvoyant"`) spend the
    /// same knob *inside* FIND — chunked REPLACE candidate
    /// generation/scoring and BALANCE move search
    /// ([`Planner::with_threads`]).  Only one layer fans out at a time
    /// ([`crate::util::nested_inner_threads`]); results are bit-identical
    /// at any thread count.
    pub threads: usize,
    /// Cooperative cancellation flag.  Policies poll it at their natural
    /// checkpoints (FIND iterations, restarts, bisection rounds) and
    /// return the best partial outcome when it fires.  The default token
    /// is never cancelled.
    pub cancel: CancelToken,
    /// Evaluator all candidate scoring goes through; `None` = the exact
    /// native evaluator.
    evaluator: Option<&'a dyn PlanEvaluator>,
}

impl<'a> SolveRequest<'a> {
    /// A request with the paper's defaults: native evaluator, default
    /// planner config, 8 multi-start restarts, oracle size estimates.
    pub fn new(budget: f64) -> Self {
        let ms = MultiStartConfig::default();
        Self {
            budget,
            deadline: None,
            seed: 0,
            planner: PlannerConfig::default(),
            n_starts: ms.n_starts,
            perf_jitter: ms.perf_jitter,
            sample_frac: 1.0,
            remaining: None,
            threads: 1,
            cancel: CancelToken::default(),
            evaluator: None,
        }
    }

    pub fn with_budget(mut self, budget: f64) -> Self {
        self.budget = budget;
        self
    }

    pub fn with_deadline(mut self, deadline: f64) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_planner(mut self, planner: PlannerConfig) -> Self {
        self.planner = planner;
        self
    }

    pub fn with_starts(mut self, n_starts: usize) -> Self {
        self.n_starts = n_starts;
        self
    }

    pub fn with_perf_jitter(mut self, perf_jitter: f64) -> Self {
        self.perf_jitter = perf_jitter;
        self
    }

    pub fn with_sample_frac(mut self, sample_frac: f64) -> Self {
        self.sample_frac = sample_frac;
        self
    }

    pub fn with_remaining(mut self, remaining: Vec<TaskId>) -> Self {
        self.remaining = Some(remaining);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Attach a cancellation token (a clone of the caller's handle).
    pub fn with_cancel(mut self, cancel: CancelToken) -> Self {
        self.cancel = cancel;
        self
    }

    pub fn with_evaluator(mut self, evaluator: &'a dyn PlanEvaluator) -> Self {
        self.evaluator = Some(evaluator);
        self
    }

    /// The evaluator to score through (native fallback when unset).
    pub fn evaluator(&self) -> &dyn PlanEvaluator {
        match self.evaluator {
            Some(e) => e,
            None => &NativeEvaluator,
        }
    }

    /// The multi-start configuration this request describes.
    pub fn multistart_config(&self) -> MultiStartConfig {
        MultiStartConfig {
            n_starts: self.n_starts,
            perf_jitter: self.perf_jitter,
            seed: self.seed,
            threads: self.threads,
            cancel: self.cancel.clone(),
            base: self.planner.clone(),
        }
    }
}

impl fmt::Debug for SolveRequest<'_> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("SolveRequest")
            .field("budget", &self.budget)
            .field("deadline", &self.deadline)
            .field("seed", &self.seed)
            .field("n_starts", &self.n_starts)
            .field("perf_jitter", &self.perf_jitter)
            .field("sample_frac", &self.sample_frac)
            .field("remaining", &self.remaining.as_ref().map(Vec::len))
            .field("threads", &self.threads)
            .field("cancelled", &self.cancel.is_cancelled())
            .field("evaluator", &self.evaluator.map(|e| e.name()))
            .field("planner", &self.planner)
            .finish()
    }
}

/// The unified result of any policy run (supersedes the per-policy
/// `FindReport` / bare-`Plan` / `DeadlineReport` return shapes).
#[derive(Debug, Clone)]
pub struct SolveOutcome {
    /// Canonical registry name of the policy that produced this outcome.
    pub policy: &'static str,
    /// The execution plan (eq. 3/4 partition of `T`).
    pub plan: Plan,
    /// Makespan (eq. 7) + realized cost (eq. 8) of `plan`.
    pub score: PlanScore,
    /// Whether the outcome satisfies the request's constraints: eq. 9 for
    /// budget policies, deadline-met for `"deadline"`.
    pub feasible: bool,
    /// Iterations of the underlying optimisation loop.
    pub iterations: usize,
    /// Planner invocations consumed (bisection probes, restarts; 1 for
    /// single-shot policies).
    pub probes: usize,
    /// The budget that produced `plan` (differs from the requested budget
    /// under `"deadline"`'s cheapest-budget search).
    pub effective_budget: f64,
}

impl SolveOutcome {
    fn from_find(policy: &'static str, budget: f64, report: FindReport) -> Self {
        Self {
            policy,
            plan: report.plan,
            score: report.score,
            feasible: report.feasible,
            iterations: report.iterations,
            probes: 1,
            effective_budget: budget,
        }
    }

    /// View as the legacy [`FindReport`] shape (compat shim).  The copy
    /// is the point here — an allow-listed boundary site of the
    /// `disallowed-methods` gate, well off the solve hot path.
    pub fn to_find_report(&self) -> FindReport {
        FindReport {
            #[allow(clippy::disallowed_methods)]
            plan: self.plan.clone(),
            score: self.score,
            feasible: self.feasible,
            iterations: self.iterations,
        }
    }
}

/// A scheduling policy: anything that turns `(system, request)` into an
/// execution plan.  Object-safe; `Send + Sync` so the coordinator can
/// serve one instance from many connection threads.
pub trait Policy: Send + Sync {
    /// Canonical registry name (`"budget-heuristic"`, `"mi"`, ...).
    fn name(&self) -> &'static str;

    /// One-line human description (shown by `list_policies` and the CLI).
    fn description(&self) -> &'static str {
        ""
    }

    /// Solve the request against `sys`.
    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome;
}

// ---------------------------------------------------------------------------
// Built-in policies.

/// The paper's Section IV contribution: Algorithm 1 (FIND) — minimise
/// makespan subject to the budget.
#[derive(Debug, Clone, Copy, Default)]
pub struct BudgetHeuristic;

impl Policy for BudgetHeuristic {
    fn name(&self) -> &'static str {
        "budget-heuristic"
    }

    fn description(&self) -> &'static str {
        "paper Sec. IV heuristic (Algorithm 1): minimise makespan under a budget"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let report = Planner::with_evaluator(sys, req.evaluator())
            .with_config(req.planner.clone())
            .with_cancel(req.cancel.clone())
            .with_threads(req.threads)
            .find(req.budget);
        SolveOutcome::from_find(self.name(), req.budget, report)
    }
}

/// Sec. V-A baseline MI: minimise individual task execution time.
#[derive(Debug, Clone, Copy, Default)]
pub struct MinimiseIndividual;

impl Policy for MinimiseIndividual {
    fn name(&self) -> &'static str {
        "mi"
    }

    fn description(&self) -> &'static str {
        "Sec. V baseline: buy the best-average-performance affordable type (MI)"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let plan = minimise_individual(sys, req.budget);
        let score = req.evaluator().eval_plan(sys, &plan);
        SolveOutcome {
            policy: self.name(),
            feasible: score.satisfies(req.budget),
            plan,
            score,
            iterations: 0,
            probes: 1,
            effective_budget: req.budget,
        }
    }
}

/// Sec. V-A baseline MP: maximise parallelism with the cheapest type.
#[derive(Debug, Clone, Copy, Default)]
pub struct MaximiseParallelism;

impl Policy for MaximiseParallelism {
    fn name(&self) -> &'static str {
        "mp"
    }

    fn description(&self) -> &'static str {
        "Sec. V baseline: as many cheapest-type VMs as the budget buys (MP)"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let plan = maximise_parallelism(sys, req.budget);
        let score = req.evaluator().eval_plan(sys, &plan);
        SolveOutcome {
            policy: self.name(),
            feasible: score.satisfies(req.budget),
            plan,
            score,
            iterations: 0,
            probes: 1,
            effective_budget: req.budget,
        }
    }
}

/// GRASP-style perturbed restarts of FIND (`n_starts`, `perf_jitter`,
/// `seed` from the request).
#[derive(Debug, Clone, Copy, Default)]
pub struct MultiStart;

impl Policy for MultiStart {
    fn name(&self) -> &'static str {
        "multistart"
    }

    fn description(&self) -> &'static str {
        "perturbed multi-start wrapper around Algorithm 1 (never worse than single-start)"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let cfg = req.multistart_config();
        let report = find_multistart(sys, req.budget, &cfg, req.evaluator());
        let mut out = SolveOutcome::from_find(self.name(), req.budget, report);
        out.probes = cfg.n_starts.max(1);
        out
    }
}

/// Sec. VI deadline extension: cheapest plan with makespan within the
/// request's `deadline`, searched by budget bisection up to `budget`.
///
/// With no deadline set the search degenerates to pure cost minimisation
/// (any budget meets an infinite deadline, so the bisection returns the
/// cheapest feasible plan).  When even the full budget cannot meet the
/// deadline, the outcome carries the best full-budget plan with
/// `feasible: false`.
#[derive(Debug, Clone, Copy, Default)]
pub struct DeadlineSearch;

impl Policy for DeadlineSearch {
    fn name(&self) -> &'static str {
        "deadline"
    }

    fn description(&self) -> &'static str {
        "Sec. VI extension: minimise cost subject to a completion deadline"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let deadline = req.deadline.unwrap_or(f64::INFINITY);
        // Every bisection probe honours the request's evaluator + config;
        // probes speculate across `req.threads` workers (bit-identical
        // at any thread count) and stop early on cancellation.
        let planner = Planner::with_evaluator(sys, req.evaluator())
            .with_config(req.planner.clone())
            .with_cancel(req.cancel.clone());
        let search = min_cost_for_deadline_ctl(&planner, deadline, req.budget, req.threads);
        match search.report {
            Some(r) => SolveOutcome {
                policy: self.name(),
                plan: r.plan,
                score: r.score,
                feasible: true,
                iterations: r.iterations,
                probes: search.probes,
                effective_budget: search.budget,
            },
            None => {
                // Deadline unreachable even at the cap: report the best
                // full-budget plan so the caller can see how far off it
                // is — the search already computed it when it probed the
                // cap (except when the cap can't buy any machine-hour).
                let (fallback, probes) = match search.best_effort {
                    Some(r) => (r, search.probes),
                    None => (planner.find(req.budget), search.probes + 1),
                };
                let mut out = SolveOutcome::from_find(self.name(), req.budget, fallback);
                out.feasible = false;
                out.probes = probes;
                out
            }
        }
    }
}

/// Sec. VI dynamic extension: re-plan a residual workload (the request's
/// `remaining` task ids; the full workload when unset) with the money
/// left.  The returned plan is expressed in parent task ids.
#[derive(Debug, Clone, Copy, Default)]
pub struct DynamicReplan;

impl Policy for DynamicReplan {
    fn name(&self) -> &'static str {
        "dynamic"
    }

    fn description(&self) -> &'static str {
        "Sec. VI extension: re-plan a residual workload mid-execution"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let mut out = match req.remaining.as_deref() {
            // A true residual subset: extract the sub-problem and re-plan.
            Some(r) if !r.is_empty() && r.len() < sys.tasks().len() => {
                super::dynamic::replan_policy(sys, r, &BudgetHeuristic, req)
            }
            // Full workload (or unset): planning the original system
            // directly is equivalent and skips the sub-system copy.
            _ => BudgetHeuristic.solve(sys, req),
        };
        out.policy = self.name();
        out
    }
}

/// Sec. VI non-clairvoyant extension: provision the fleet from sampled
/// size estimates (the request's `sample_frac` / `seed`), then assign the
/// *real* workload onto it.  At run time the plan's pinning would be
/// replaced by online self-scheduling (`Simulator::run_online`); the
/// returned plan is the clairvoyant re-assignment used for scoring.
#[derive(Debug, Clone, Copy, Default)]
pub struct NonClairvoyant;

impl Policy for NonClairvoyant {
    fn name(&self) -> &'static str {
        "nonclairvoyant"
    }

    fn description(&self) -> &'static str {
        "Sec. VI extension: provision from sampled size estimates, dispatch online"
    }

    fn solve(&self, sys: &System, req: &SolveRequest) -> SolveOutcome {
        let mut rng = Rng::new(req.seed);
        let frac = req.sample_frac.clamp(1e-9, 1.0);
        let belief = surrogate_system(sys, frac, &mut rng);
        let fleet = Planner::with_evaluator(&belief, req.evaluator())
            .with_config(req.planner.clone())
            .with_cancel(req.cancel.clone())
            .with_threads(req.threads)
            .find(req.budget);

        // Transplant the fleet onto the true system and re-assign the
        // real tasks (only the provisioning decision transfers).
        let mut plan = Plan::new();
        for vm in &fleet.plan.vms {
            plan.add_vm(sys, vm.it);
        }
        if plan.vms.is_empty() {
            plan.add_vm(sys, sys.cheapest_type());
        }
        let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
        assign(sys, &mut plan, &tasks);
        let cap = req.budget.max(plan.cost(sys));
        balance(sys, &mut plan, cap);
        plan.drop_empty_vms();

        let score = req.evaluator().eval_plan(sys, &plan);
        SolveOutcome {
            policy: self.name(),
            feasible: score.satisfies(req.budget),
            plan,
            score,
            iterations: fleet.iterations,
            probes: 1,
            effective_budget: req.budget,
        }
    }
}

// ---------------------------------------------------------------------------
// Registry.

/// Lookup failure: the requested name is not registered.
#[derive(Debug, Clone)]
pub struct UnknownPolicy {
    pub name: String,
    pub known: Vec<&'static str>,
}

impl fmt::Display for UnknownPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "unknown policy {:?} (known: {})",
            self.name,
            self.known.join(", ")
        )
    }
}

impl std::error::Error for UnknownPolicy {}

/// Name → policy resolution.  [`PolicyRegistry::builtin`] registers the
/// seven shipped policies; callers can [`register`](Self::register) more.
/// This is the extension point for new scheduling scenarios: implement
/// [`Policy`], register it, and every consumer (coordinator wire
/// protocol, cloudsim campaigns, sweep reports, CLI, benches) can run it
/// by name.
pub struct PolicyRegistry {
    entries: BTreeMap<&'static str, Arc<dyn Policy>>,
}

impl PolicyRegistry {
    /// An empty registry.
    pub fn empty() -> Self {
        Self { entries: BTreeMap::new() }
    }

    /// All seven built-in policies.
    pub fn builtin() -> Self {
        let mut r = Self::empty();
        r.register(BudgetHeuristic);
        r.register(MinimiseIndividual);
        r.register(MaximiseParallelism);
        r.register(MultiStart);
        r.register(DeadlineSearch);
        r.register(DynamicReplan);
        r.register(NonClairvoyant);
        r
    }

    /// Register a policy under its [`Policy::name`] (replacing any
    /// previous entry with that name).
    pub fn register<P: Policy + 'static>(&mut self, policy: P) {
        self.register_arc(Arc::new(policy));
    }

    /// Register a shared policy instance.
    pub fn register_arc(&mut self, policy: Arc<dyn Policy>) {
        self.entries.insert(policy.name(), policy);
    }

    /// Resolve `name` (aliases accepted, see [`canonical_name`]).
    pub fn get(&self, name: &str) -> Option<&dyn Policy> {
        self.entries.get(canonical_name(name)).map(|p| p.as_ref())
    }

    /// Resolve `name` to a shareable handle (e.g. for a `CampaignSpec`).
    pub fn get_arc(&self, name: &str) -> Option<Arc<dyn Policy>> {
        self.entries.get(canonical_name(name)).cloned()
    }

    /// Like [`get`](Self::get) but with a descriptive error.
    pub fn resolve(&self, name: &str) -> Result<&dyn Policy, UnknownPolicy> {
        self.get(name).ok_or_else(|| self.unknown(name))
    }

    /// Like [`get_arc`](Self::get_arc) but with a descriptive error.
    pub fn resolve_arc(&self, name: &str) -> Result<Arc<dyn Policy>, UnknownPolicy> {
        self.get_arc(name).ok_or_else(|| self.unknown(name))
    }

    /// Resolve and run in one step.
    pub fn solve(
        &self,
        name: &str,
        sys: &System,
        req: &SolveRequest,
    ) -> Result<SolveOutcome, UnknownPolicy> {
        Ok(self.resolve(name)?.solve(sys, req))
    }

    /// Registered canonical names, sorted.
    pub fn names(&self) -> Vec<&'static str> {
        self.entries.keys().copied().collect()
    }

    /// Registered policies, sorted by name.
    pub fn iter(&self) -> impl Iterator<Item = &dyn Policy> {
        self.entries.values().map(|p| p.as_ref())
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    fn unknown(&self, name: &str) -> UnknownPolicy {
        UnknownPolicy { name: name.to_string(), known: self.names() }
    }
}

impl Default for PolicyRegistry {
    fn default() -> Self {
        Self::builtin()
    }
}

impl fmt::Debug for PolicyRegistry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("PolicyRegistry").field("names", &self.names()).finish()
    }
}

/// Canonical names of the built-in policies, in registry order.
pub const BUILTIN_POLICIES: &[&str] = &[
    "budget-heuristic",
    "deadline",
    "dynamic",
    "mi",
    "mp",
    "multistart",
    "nonclairvoyant",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;

    #[test]
    fn builtin_registry_resolves_every_name() {
        let r = PolicyRegistry::builtin();
        assert_eq!(r.names(), BUILTIN_POLICIES);
        for &name in BUILTIN_POLICIES {
            let p = r.get(name).unwrap_or_else(|| panic!("{name} missing"));
            assert_eq!(p.name(), name);
            assert!(!p.description().is_empty(), "{name} needs a description");
        }
    }

    #[test]
    fn aliases_resolve_to_canonical_policies() {
        let r = PolicyRegistry::builtin();
        assert_eq!(r.get("heuristic").unwrap().name(), "budget-heuristic");
        assert_eq!(r.get("non-clairvoyant").unwrap().name(), "nonclairvoyant");
        assert_eq!(r.get("multi-start").unwrap().name(), "multistart");
    }

    #[test]
    fn unknown_name_is_a_descriptive_error() {
        let r = PolicyRegistry::builtin();
        assert!(r.get("nope").is_none());
        let err = r
            .solve("nope", &table1_system(0.0), &SolveRequest::new(80.0))
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("nope"), "{msg}");
        assert!(msg.contains("budget-heuristic"), "{msg}");
    }

    #[test]
    fn every_builtin_returns_a_valid_partition() {
        let sys = table1_system(0.0);
        let r = PolicyRegistry::builtin();
        let req = SolveRequest::new(80.0).with_deadline(2.0 * 3600.0).with_starts(2);
        for &name in BUILTIN_POLICIES {
            let out = r.solve(name, &sys, &req).unwrap();
            assert_eq!(out.policy, name);
            assert!(
                out.plan.validate_partition(&sys).is_ok(),
                "{name}: invalid partition"
            );
            assert!(out.probes >= 1, "{name}: no probes recorded");
            assert!(out.score.makespan > 0.0, "{name}: empty score");
        }
    }

    #[test]
    fn custom_policy_registration() {
        struct Always80;
        impl Policy for Always80 {
            fn name(&self) -> &'static str {
                "always-80"
            }
            fn solve(&self, sys: &System, _req: &SolveRequest) -> SolveOutcome {
                BudgetHeuristic.solve(sys, &SolveRequest::new(80.0))
            }
        }
        let mut r = PolicyRegistry::builtin();
        r.register(Always80);
        assert_eq!(r.len(), BUILTIN_POLICIES.len() + 1);
        let sys = table1_system(0.0);
        let out = r.solve("always-80", &sys, &SolveRequest::new(1.0)).unwrap();
        assert!(out.feasible); // solved at 80, not at the requested 1
    }

    #[test]
    fn deadline_without_deadline_minimises_cost() {
        let sys = table1_system(0.0);
        let out = PolicyRegistry::builtin()
            .solve("deadline", &sys, &SolveRequest::new(200.0))
            .unwrap();
        assert!(out.feasible);
        // The cheapest way to run the workload is well under the cap.
        assert!(out.score.cost < 200.0);
        assert!(out.effective_budget <= 200.0);
        assert!(out.probes > 1, "bisection should probe repeatedly");
    }

    #[test]
    fn nonclairvoyant_covers_the_real_workload() {
        let sys = table1_system(0.0);
        let out = PolicyRegistry::builtin()
            .solve("nonclairvoyant", &sys, &SolveRequest::new(80.0).with_sample_frac(0.2))
            .unwrap();
        assert!(out.plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn dynamic_defaults_to_full_workload() {
        let sys = table1_system(0.0);
        let out = PolicyRegistry::builtin()
            .solve("dynamic", &sys, &SolveRequest::new(80.0))
            .unwrap();
        assert!(out.plan.validate_partition(&sys).is_ok());
        assert!(out.feasible);
    }

    #[test]
    fn solve_request_builder_roundtrip() {
        let req = SolveRequest::new(70.0)
            .with_deadline(3600.0)
            .with_seed(9)
            .with_starts(3)
            .with_perf_jitter(0.1)
            .with_sample_frac(0.5)
            .with_threads(4)
            .with_remaining(vec![TaskId(0), TaskId(1)]);
        assert_eq!(req.budget, 70.0);
        assert_eq!(req.deadline, Some(3600.0));
        assert_eq!(req.seed, 9);
        let ms = req.multistart_config();
        assert_eq!(ms.n_starts, 3);
        assert_eq!(ms.perf_jitter, 0.1);
        assert_eq!(ms.seed, 9);
        assert_eq!(ms.threads, 4);
        assert_eq!(req.remaining.as_ref().map(Vec::len), Some(2));
        assert_eq!(req.evaluator().name(), NativeEvaluator.name());
        // Debug must not require the evaluator to be Debug.
        let dbg = format!("{req:?}");
        assert!(dbg.contains("budget"));
    }
}
