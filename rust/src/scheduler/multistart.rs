//! Multi-start (GRASP-style) wrapper around Algorithm 1.
//!
//! FIND is a deterministic greedy fixed-point, so it can sit in a local
//! optimum.  The multi-start planner runs `n_starts` perturbed restarts:
//! each restart plans against a *jittered belief* of the performance
//! matrix (every `P[it, app]` scaled by `uniform(1 ± perf_jitter)`),
//! which diversifies the instance-type choices INITIAL/ADD/REPLACE make;
//! the resulting plan is then re-scored against the **true** system and
//! the best feasible outcome wins.  This is an in-scope strengthening of
//! the paper's approach (its related work leans on iterated heuristics)
//! and also models planning under estimation error — the same mechanism
//! as `nonclairvoyant::surrogate_system`, applied to `P` instead of task
//! sizes.
//!
//! Restarts are independent, so they run on the
//! [`crate::util::parallel`] worker pool (`MultiStartConfig::threads`;
//! 1 = sequential, 0 = auto).  Determinism is preserved by *deriving
//! every restart's perturbed belief up front* from the shared RNG stream
//! — the same draws in the same order as the historical sequential loop
//! — and merging worker results in restart order, so the outcome is
//! bit-identical at any thread count (pinned by the `perf_parity`
//! integration tests and the unit tests below).
//!
//! FIND itself now has intra-solve parallelism
//! ([`Planner::with_threads`]); only one layer fans out at a time.  When
//! the restart loop runs on more than one worker, each restart's FIND is
//! forced sequential via [`crate::util::nested_inner_threads`]; a
//! sequential restart loop (`threads == 1` or a single start) passes the
//! thread budget down into FIND instead.  Either way the plans are
//! bit-identical — the split only decides *where* the threads are spent.

use crate::eval::{DeltaBatch, NativeEvaluator, PlanEvaluator};
use crate::model::{Plan, System, SystemBuilder};
use crate::util::{CancelToken, Rng};

use super::find::{FindReport, Planner, PlannerConfig};

/// Multi-start configuration.
#[derive(Debug, Clone)]
pub struct MultiStartConfig {
    pub n_starts: usize,
    /// Relative perturbation applied to each perf-matrix cell per restart.
    pub perf_jitter: f64,
    pub seed: u64,
    /// Worker threads for the restarts (1 = sequential, 0 = auto-detect;
    /// see [`crate::util::parallel`]).  Any value yields bit-identical
    /// results.
    pub threads: usize,
    /// Cooperative cancellation: restarts `1..n_starts` not yet begun
    /// when the token fires are skipped (restart 0 — the unperturbed
    /// FIND — always runs, so a cancelled multistart still returns a
    /// scored plan).  The default token never fires.
    pub cancel: CancelToken,
    pub base: PlannerConfig,
}

impl Default for MultiStartConfig {
    fn default() -> Self {
        Self {
            n_starts: 8,
            perf_jitter: 0.25,
            seed: 0,
            threads: 1,
            cancel: CancelToken::default(),
            base: PlannerConfig::default(),
        }
    }
}

/// Build a belief system with every perf cell scaled by
/// `uniform(1 - jitter, 1 + jitter)` (same apps, tasks and prices).
fn perturbed_system(sys: &System, jitter: f64, rng: &mut Rng) -> System {
    let mut b = SystemBuilder::new()
        .overhead(sys.overhead)
        .hour(sys.hour)
        .billing(sys.billing);
    for app in &sys.apps {
        b = b.app(&app.name, app.task_sizes.clone());
    }
    for it in &sys.instance_types {
        let row: Vec<f64> = sys
            .perf
            .row(it.id)
            .iter()
            .map(|p| (p * rng.uniform(1.0 - jitter, 1.0 + jitter)).max(1e-6))
            .collect();
        b = b.instance_type(&it.name, it.cost_per_hour, row);
    }
    b.build().expect("perturbation preserves validity")
}

/// Transplant a plan built against a belief system onto the true system
/// (identical catalogue and task ids, different perf values).
fn transplant(sys: &System, plan: &Plan) -> Plan {
    let mut out = Plan::new();
    for vm in &plan.vms {
        let idx = out.add_vm(sys, vm.it);
        for &t in vm.tasks() {
            out.vms[idx].push_task(sys, t);
        }
    }
    out
}

/// Run perturbed restarts of FIND and keep the best plan.
///
/// "Best" follows Algorithm 1's preference order: a feasible plan beats
/// any infeasible one; among equals the lower makespan wins (cost as the
/// tie-break).
///
/// Restart 0 is the unperturbed FIND on the true system; restarts
/// `1..n_starts` plan against perturbed beliefs.  The beliefs are
/// derived sequentially up front (consuming the seed's RNG stream
/// exactly as the historical sequential loop did), the planning fans out
/// over [`crate::util::parallel_map`], and the winners merge in restart
/// order — so the result does not depend on `config.threads`.
pub fn find_multistart(
    sys: &System,
    budget: f64,
    config: &MultiStartConfig,
    evaluator: &dyn PlanEvaluator,
) -> FindReport {
    let n_starts = config.n_starts.max(1);
    let mut rng = Rng::new(config.seed);
    let beliefs: Vec<System> = (1..n_starts)
        .map(|_| perturbed_system(sys, config.perf_jitter, &mut rng))
        .collect();

    // One parallel layer at a time: when the restart fan-out itself runs
    // on >1 worker, each restart's FIND stays sequential inside; a
    // sequential fan-out passes the thread budget down instead.
    let inner_threads = crate::util::nested_inner_threads(config.threads, n_starts);

    let reports = crate::util::parallel_map(config.threads, n_starts, |i| {
        if i == 0 {
            // The unperturbed baseline always starts (it is never
            // skipped like restarts 1..), so a cancelled multistart
            // still has an outcome: FIND's cancel checkpoint sits after
            // an iteration is stored, so even a cancelled restart 0
            // returns a fully scored plan.
            return Some(
                Planner::with_evaluator(sys, evaluator)
                    .with_config(config.base.clone())
                    .with_cancel(config.cancel.clone())
                    .with_threads(inner_threads)
                    .find(budget),
            );
        }
        if config.cancel.is_cancelled() {
            return None; // restart skipped: cancelled before it began
        }
        let belief = &beliefs[i - 1];
        let candidate = Planner::new(belief)
            .with_config(config.base.clone())
            .with_cancel(config.cancel.clone())
            .with_threads(inner_threads)
            .find(budget);
        // Re-anchor on the true system: transplant the assignment, then
        // let BALANCE repair what the belief distorted.
        let mut plan = transplant(sys, &candidate.plan);
        let cap = budget.max(plan.cost(sys));
        super::balance(sys, &mut plan, cap);
        // Re-score on the true system through the zero-clone delta path
        // (bit-identical to `eval_plan`; pinned by `arena_parity`).
        let score = NativeEvaluator.eval_deltas(&DeltaBatch::from_plan(sys, &plan))[0];
        let feasible = score.satisfies(budget);
        Some(FindReport { plan, score, feasible, iterations: candidate.iterations })
    });

    let mut it = reports.into_iter().flatten();
    let mut best = it.next().expect("restart 0 always runs");
    for candidate in it {
        let better = match (candidate.feasible, best.feasible) {
            (true, false) => true,
            (false, true) => false,
            _ => {
                (candidate.score.makespan, candidate.score.cost)
                    < (best.score.makespan, best.score.cost)
            }
        };
        if better {
            best = candidate;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::paper::table1_system;
    use crate::workload::{WorkloadGenerator, WorkloadSpec};

    #[test]
    fn never_worse_than_single_start() {
        let sys = table1_system(0.0);
        for &b in &[60.0, 70.0, 85.0] {
            let single = Planner::new(&sys).find(b);
            let multi = find_multistart(&sys, b, &MultiStartConfig::default(), &NativeEvaluator);
            assert!(multi.plan.validate_partition(&sys).is_ok());
            if single.feasible {
                assert!(multi.feasible);
                assert!(
                    multi.score.makespan <= single.score.makespan + 1e-6,
                    "budget {b}: multi {} worse than single {}",
                    multi.score.makespan,
                    single.score.makespan
                );
            }
        }
    }

    /// The historical (pre-parallel) sequential implementation, kept
    /// verbatim as the parity reference: one shared RNG stream,
    /// belief generation interleaved with planning.
    fn legacy_sequential(
        sys: &System,
        budget: f64,
        config: &MultiStartConfig,
        evaluator: &dyn PlanEvaluator,
    ) -> FindReport {
        let mut rng = Rng::new(config.seed);
        let planner = Planner::with_evaluator(sys, evaluator).with_config(config.base.clone());
        let mut best = planner.find(budget);
        for _ in 1..config.n_starts.max(1) {
            let belief = perturbed_system(sys, config.perf_jitter, &mut rng);
            let candidate = Planner::new(&belief).with_config(config.base.clone()).find(budget);
            let mut plan = transplant(sys, &candidate.plan);
            let cap = budget.max(plan.cost(sys));
            crate::scheduler::balance(sys, &mut plan, cap);
            let score = NativeEvaluator.eval_plan(sys, &plan);
            let feasible = score.satisfies(budget);
            let better = match (feasible, best.feasible) {
                (true, false) => true,
                (false, true) => false,
                _ => (score.makespan, score.cost) < (best.score.makespan, best.score.cost),
            };
            if better {
                best = FindReport { plan, score, feasible, iterations: candidate.iterations };
            }
        }
        best
    }

    #[test]
    fn parallel_restarts_bit_identical_to_legacy_sequential() {
        let sys = table1_system(0.0);
        for &budget in &[60.0, 80.0] {
            let cfg = MultiStartConfig { n_starts: 5, seed: 21, ..Default::default() };
            let legacy = legacy_sequential(&sys, budget, &cfg, &NativeEvaluator);
            for threads in [1usize, 2, 4] {
                let cfg = MultiStartConfig { threads, ..cfg.clone() };
                let got = find_multistart(&sys, budget, &cfg, &NativeEvaluator);
                assert_eq!(
                    got.score.makespan.to_bits(),
                    legacy.score.makespan.to_bits(),
                    "budget {budget}, threads {threads}: makespan bits differ"
                );
                assert_eq!(
                    got.score.cost.to_bits(),
                    legacy.score.cost.to_bits(),
                    "budget {budget}, threads {threads}: cost bits differ"
                );
                assert_eq!(got.feasible, legacy.feasible);
                assert_eq!(got.iterations, legacy.iterations);
                assert_eq!(got.plan.n_vms(), legacy.plan.n_vms());
                for (a, b) in got.plan.vms.iter().zip(&legacy.plan.vms) {
                    assert_eq!(a.it, b.it, "budget {budget}, threads {threads}");
                    assert_eq!(a.tasks(), b.tasks(), "budget {budget}, threads {threads}");
                }
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let sys = table1_system(0.0);
        let cfg = MultiStartConfig { n_starts: 4, seed: 9, ..Default::default() };
        let a = find_multistart(&sys, 80.0, &cfg, &NativeEvaluator);
        let b = find_multistart(&sys, 80.0, &cfg, &NativeEvaluator);
        assert_eq!(a.score.makespan, b.score.makespan);
        assert_eq!(a.score.cost, b.score.cost);
    }

    #[test]
    fn perturbed_system_preserves_structure() {
        let sys = table1_system(30.0);
        let mut rng = Rng::new(3);
        let belief = perturbed_system(&sys, 0.2, &mut rng);
        assert_eq!(belief.n_apps(), 3);
        assert_eq!(belief.n_types(), 4);
        assert_eq!(belief.tasks().len(), 750);
        assert_eq!(belief.overhead, 30.0);
        // Perf actually changed, prices did not.
        let mut any_diff = false;
        for it in &sys.instance_types {
            assert_eq!(belief.rate(it.id), sys.rate(it.id));
            if belief.perf.row(it.id) != sys.perf.row(it.id) {
                any_diff = true;
            }
        }
        assert!(any_diff);
    }

    #[test]
    fn transplant_preserves_partition_and_rescoring() {
        let sys = table1_system(0.0);
        let mut rng = Rng::new(5);
        let belief = perturbed_system(&sys, 0.3, &mut rng);
        let plan = Planner::new(&belief).find(80.0).plan;
        let real = transplant(&sys, &plan);
        assert!(real.validate_partition(&sys).is_ok());
        assert_eq!(real.n_vms(), plan.n_vms());
    }

    #[test]
    fn helps_or_ties_on_random_instances() {
        let mut gen = WorkloadGenerator::new(77);
        let mut cases = 0;
        for seed in 0..10u64 {
            let spec = WorkloadSpec {
                n_apps: 2 + (seed % 3) as usize,
                n_types: 3 + (seed % 3) as usize,
                tasks_per_app: 60,
                ..Default::default()
            };
            let sys = gen.system(&spec);
            let budget = WorkloadGenerator::feasible_budget(&sys, 1.5);
            let single = Planner::new(&sys).find(budget);
            let cfg = MultiStartConfig { n_starts: 6, seed, ..Default::default() };
            let multi = find_multistart(&sys, budget, &cfg, &NativeEvaluator);
            assert!(multi.plan.validate_partition(&sys).is_ok(), "seed {seed}");
            if !single.feasible {
                continue;
            }
            cases += 1;
            assert!(
                multi.feasible && multi.score.makespan <= single.score.makespan + 1e-6,
                "seed {seed}: multi must not be worse"
            );
        }
        assert!(cases >= 5, "too few feasible cases");
    }
}
