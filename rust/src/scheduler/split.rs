//! Sec. IV-F `SPLIT` (the paper's *KEEP*): keep VM run times within one
//! billed hour.
//!
//! Under hourly billing, one VM running two hours costs the same as two
//! VMs of the same type running one hour each — but the two-VM version
//! halves the completion time.  SPLIT therefore repeatedly takes a VM
//! whose execution time exceeds one hour and splits its tasks across two
//! VMs of the same instance type, keeping the split only when the budget
//! still holds and the overall execution time strictly drops.

use crate::model::{Plan, System, TaskId};

/// Split over-hour VMs while it helps.  Returns the number of splits.
pub fn split(sys: &System, plan: &mut Plan, budget: f64) -> usize {
    let mut splits = 0usize;
    // Each split adds one VM; cap to prevent pathological growth.
    let cap = plan.n_vms() * 8 + 16;
    while splits < cap {
        if !try_split_one(sys, plan, budget) {
            break;
        }
        splits += 1;
    }
    splits
}

/// Split the longest-running over-hour VM; returns success.
///
/// Acceptance: the budget must hold, the overall makespan must not
/// increase, and the victim's own execution time must strictly drop.  The
/// paper asks for a strict *overall* decrease, but with several VMs tied
/// at the makespan that test deadlocks (splitting one tied VM leaves the
/// others defining the makespan); requiring per-victim progress instead
/// lets the ties resolve one by one and still terminates (every accepted
/// split strictly shrinks some VM's run time).
fn try_split_one(sys: &System, plan: &mut Plan, budget: f64) -> bool {
    let before = plan.score(sys);
    let Some((victim, victim_exec)) = plan
        .vms
        .iter()
        .enumerate()
        .map(|(i, vm)| (i, vm.exec(sys)))
        .filter(|(i, e)| *e > sys.hour && plan.vms[*i].len() >= 2)
        .max_by(|a, b| a.1.total_cmp(&b.1))
    else {
        return false;
    };

    // Genuine copy (allow-listed boundary site of the `disallowed-methods`
    // gate): the accept test needs the untouched plan to fall back to.
    #[allow(clippy::disallowed_methods)]
    let mut scratch = plan.clone();
    let it = scratch.vms[victim].it;
    let twin = scratch.add_vm(sys, it);
    // LPT re-partition of the victim's tasks across {victim, twin}: longest
    // task first onto the emptier half; both halves share the instance
    // type, so exec time is the right load measure.
    let mut tasks: Vec<TaskId> = scratch.vms[victim].drain_tasks();
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    for t in tasks {
        let dst = if scratch.vms[victim].work() <= scratch.vms[twin].work() { victim } else { twin };
        scratch.vms[dst].push_task(sys, t);
    }
    let after = scratch.score(sys);
    let new_victim_exec = scratch.vms[victim].exec(sys).max(scratch.vms[twin].exec(sys));
    if after.cost <= budget + 1e-9
        && after.makespan <= before.makespan + 1e-9
        && new_victim_exec < victim_exec - 1e-9
    {
        *plan = scratch;
        true
    } else {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{InstanceTypeId, SystemBuilder};

    fn sys() -> System {
        SystemBuilder::new()
            .app("a", vec![1000.0; 8])
            .instance_type("x", 5.0, vec![1.0]) // 1000s per task
            .build()
            .unwrap()
    }

    #[test]
    fn splits_two_hour_vm_given_budget() {
        let s = sys();
        let mut p = Plan::new();
        let v = p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v].push_task(&s, t.id); // 8000s -> 3 billed hours, cost 15
        }
        let n = split(&s, &mut p, 20.0);
        assert!(n >= 1);
        let score = p.score(&s);
        assert!(score.makespan < 8000.0);
        assert!(score.cost <= 20.0);
        assert!(p.validate_partition(&s).is_ok());
    }

    #[test]
    fn no_split_without_budget() {
        let s = sys();
        let mut p = Plan::new();
        let v = p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v].push_task(&s, t.id);
        }
        // cost is already 15; a split to 2 VMs x 4000s = 2h each -> 20 > 15.
        assert_eq!(split(&s, &mut p, 15.0), 0);
        assert_eq!(p.n_vms(), 1);
    }

    #[test]
    fn under_hour_vm_untouched() {
        let s = SystemBuilder::new()
            .app("a", vec![10.0; 4])
            .instance_type("x", 5.0, vec![1.0])
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v = p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v].push_task(&s, t.id); // 40s, well under an hour
        }
        assert_eq!(split(&s, &mut p, 1000.0), 0);
    }

    #[test]
    fn single_task_vm_cannot_split() {
        let s = SystemBuilder::new()
            .app("a", vec![8000.0])
            .instance_type("x", 5.0, vec![1.0])
            .build()
            .unwrap();
        let mut p = Plan::new();
        let v = p.add_vm(&s, InstanceTypeId(0));
        p.vms[v].push_task(&s, crate::model::TaskId(0));
        assert_eq!(split(&s, &mut p, 1000.0), 0);
    }

    #[test]
    fn split_cascades_to_quarters_when_it_pays() {
        let s = sys();
        let mut p = Plan::new();
        let v = p.add_vm(&s, InstanceTypeId(0));
        for t in s.tasks() {
            p.vms[v].push_task(&s, t.id); // 8000s
        }
        split(&s, &mut p, 100.0);
        // With ample budget the 8000s pool ends as 3+ VMs all under ~1h.
        assert!(p.n_vms() >= 3);
        let max_exec = p.vms.iter().map(|vm| vm.exec(&s)).fold(0.0, f64::max);
        assert!(max_exec <= 2.0 * 3600.0);
        assert!(p.validate_partition(&s).is_ok());
    }
}
