//! Sec. IV-G `REPLACE`: swap expensive VMs for more, cheaper ones.
//!
//! Fewer fast-but-expensive VMs can lose to many moderate-but-cheap ones
//! (the paper's it_1-vs-it_2 example).  REPLACE picks `k` VMs of one
//! instance type, frees their billed cost, buys as many VMs of a cheaper
//! type as the freed cost plus any remaining budget affords (one-hour
//! price assumption), re-assigns the victims' tasks onto the new VMs only,
//! and commits the swap iff the budget still holds and the overall
//! execution time strictly drops.
//!
//! **Zero-clone delta batching over arena rows.**  Candidate swaps are
//! scored without materialising candidate plans: because a plan's score
//! depends on its assignment only through each VM's per-application
//! aggregated sizes (eq. 5 is linear in task size), a candidate is fully
//! described by the surviving VMs' aggregation rows — *borrowed* straight
//! out of [`PlanArena`]'s contiguous slot-major storage — plus `n_new`
//! synthesised rows for the replacement VMs (an LPT spread over
//! aggregated sizes, no `TaskId` routing).  All `(source type, cheaper
//! type)` alternatives form one [`DeltaBatch`] scored **in one evaluator
//! call** — this is the planner hot path that the AOT-compiled XLA
//! artifact accelerates in the coordinator.  Only the winning swap is
//! materialised, by mutating the arena in place (freed slots recycle via
//! the arena's free list; no `Vec<Vm>` shifting); the rejected candidates
//! never allocate more than their synthesised rows.  The `perf_parity`
//! and `arena_parity` integration tests pin this path bit-for-bit
//! against the historical clone-per-candidate implementation.
//!
//! **Bound-based candidate pruning** (on by default, [`ReplaceOpts`]):
//! before any LPT row is synthesised, each `(victim set, cheaper type)`
//! pair is tested against a lower bound on the best makespan it could
//! possibly achieve — the max of the surviving rows' execution times and
//! [`crate::analysis::bounds::spread_makespan_floor`] over the drained
//! work.  A candidate whose bound cannot beat the strict-improvement
//! commit test (`makespan < before - 1e-9`) can never win *or* commit,
//! so skipping it is threshold-exact: the selected winner is unchanged,
//! pinned by the `parallel_parity` suite.  [`ReplaceProbe`] counts
//! enumerated / pruned / synthesised candidates so the win is asserted
//! (tests) and measured (`planner_micro/parallel` bench), not assumed.
//!
//! **Threading** ([`ReplaceOpts::threads`]): candidate *generation*
//! (surviving-row collection + LPT synthesis) is partitioned across the
//! [`crate::util::parallel`] pool per candidate and merged back in the
//! historical enumeration order, and scoring fans out through
//! [`crate::eval::eval_deltas_chunked`].  Both merges are ordered and
//! every candidate is a pure function of the (shared, immutable) arena,
//! so plans are bit-identical at any thread count.  Cancellation
//! abandons the whole round before anything is committed — the arena is
//! left untouched, exactly like the sequential path.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::analysis::bounds::spread_makespan_floor;
use crate::eval::{eval_deltas_chunked, DeltaBatch, DeltaCandidate, PlanArena, PlanEvaluator};
use crate::model::{InstanceTypeId, Plan, System, TaskId};
use crate::util::{parallel_map, CancelToken};

/// Evenly distribute `tasks` over the (same-typed) new VMs: longest
/// processing time first onto the least-loaded VM.  The paper's Sec. IV-G
/// example states "tasks are evenly distributed to both VMs"; LPT is the
/// standard way to realise that for identical machines.
fn lpt_spread(sys: &System, arena: &mut PlanArena, mut tasks: Vec<TaskId>, vms: &[usize]) {
    let it = arena.it_at(vms[0]);
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    for t in tasks {
        let dst = *vms
            .iter()
            .min_by(|&&a, &&b| arena.work_at(a).total_cmp(&arena.work_at(b)))
            .expect("at least one new VM");
        arena.push_task(sys, dst, t);
    }
}

/// Simulate [`lpt_spread`] over `n_new` fresh VMs of type `it` without an
/// arena: same sort, same first-minimum destination choice, same
/// accumulation order as `Vm::push_task`, so the resulting per-VM
/// aggregated sizes are float-for-float what the materialised spread
/// would cache.  Returns one aggregation row per new VM that received at
/// least one task (empty new VMs would be removed by `drop_empty_vms`).
fn lpt_agg_rows(
    sys: &System,
    mut tasks: Vec<TaskId>,
    it: InstanceTypeId,
    n_new: usize,
) -> Vec<Vec<f64>> {
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    let mut work = vec![0.0f64; n_new];
    let mut agg = vec![vec![0.0f64; sys.n_apps()]; n_new];
    let mut used = vec![false; n_new];
    for t in tasks {
        let dst = (0..n_new)
            .min_by(|&a, &b| work[a].total_cmp(&work[b]))
            .expect("n_new > 0");
        work[dst] += sys.exec_time(it, t);
        let task = sys.task(t);
        agg[dst][task.app.index()] += task.size;
        used[dst] = true;
    }
    agg.into_iter()
        .zip(used)
        .filter_map(|(a, u)| u.then_some(a))
        .collect()
}

/// One candidate swap, described symbolically until (and unless) it wins.
struct Swap {
    victims: Vec<usize>,
    cheap: InstanceTypeId,
    n_new: usize,
}

/// Telemetry counters for REPLACE rounds, shared-nothing per caller (no
/// process-global state): hand one to [`ReplaceOpts::probe`] and read it
/// back after the call.  Counters accumulate across rounds; increments
/// are relaxed atomics so the parallel generation workers can report.
#[derive(Debug, Default)]
pub struct ReplaceProbe {
    /// `(victim set, cheaper type)` pairs enumerated (before pruning).
    pub enumerated: AtomicU64,
    /// Pairs skipped by the bound-based pruning — no LPT synthesis, no
    /// scoring, no allocation beyond the O(apps) bound itself.
    pub pruned: AtomicU64,
    /// LPT row syntheses actually performed (one per surviving pair).
    pub synth_calls: AtomicU64,
}

impl ReplaceProbe {
    /// `(enumerated, pruned, synth_calls)` so far.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.enumerated.load(Ordering::Relaxed),
            self.pruned.load(Ordering::Relaxed),
            self.synth_calls.load(Ordering::Relaxed),
        )
    }
}

/// Tuning knobs for [`replace_arena_opts`].  The defaults (sequential,
/// pruning on, no probe) are what the 6-argument [`replace_arena`]
/// wrapper uses; any combination produces bit-identical plans.
#[derive(Debug, Clone, Copy)]
pub struct ReplaceOpts<'p> {
    /// Worker threads for candidate generation + chunked scoring
    /// ([`crate::util::parallel`] contract: `0` = auto, `1` = inline
    /// sequential).  Callers nested under a parallel outer level must
    /// pass `1` (see [`crate::util::nested_inner_threads`]).
    pub threads: usize,
    /// Bound-based candidate pruning.  Threshold-exact — disabling it
    /// changes throughput, never the selected winner.
    pub prune: bool,
    /// Optional telemetry sink.
    pub probe: Option<&'p ReplaceProbe>,
}

impl Default for ReplaceOpts<'_> {
    fn default() -> Self {
        Self { threads: 1, prune: true, probe: None }
    }
}

/// Try one replacement round; commits at most one swap (the paper
/// considers "only one instance type at a time").  Returns true if a swap
/// was applied.
///
/// `Plan`-level wrapper around [`replace_arena`]; the store-back is
/// skipped when no swap committed.
pub fn replace(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
) -> bool {
    replace_cancellable(sys, plan, budget, k, evaluator, &CancelToken::default())
}

/// [`replace`] with a cooperative cancellation checkpoint (see
/// [`replace_arena`]).
pub fn replace_cancellable(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
    cancel: &CancelToken,
) -> bool {
    let mut arena = PlanArena::from_plan(sys, plan);
    let swapped = replace_arena(sys, &mut arena, budget, k, evaluator, cancel);
    if swapped {
        arena.store_plan(plan);
    }
    swapped
}

/// One replacement round on arena state, in place, with the default
/// options (sequential, pruning on): see [`replace_arena_opts`].
pub fn replace_arena(
    sys: &System,
    arena: &mut PlanArena,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
    cancel: &CancelToken,
) -> bool {
    replace_arena_opts(sys, arena, budget, k, evaluator, cancel, &ReplaceOpts::default())
}

/// One victim set (all victims share a source type) plus everything the
/// pruning bound and the candidate builders need to know about it.
struct VictimGroup {
    victims: Vec<usize>,
    is_victim: Vec<bool>,
    /// The tasks a materialised swap would drain, in drain order.
    drained: Vec<TaskId>,
    /// Per-app aggregated size of the drained tasks.
    drained_agg: Vec<f64>,
    /// Per-app largest single drained task size.
    drained_max: Vec<f64>,
    freed: f64,
    src_rate: f64,
    /// Max execution time among the rows surviving this victim set.
    surviving_max_exec: f64,
}

/// One replacement round on arena state, in place.  Returns true if a
/// swap was applied.
///
/// Three phases, all bit-identical to the historical sequential
/// implementation at any [`ReplaceOpts::threads`] and with pruning on or
/// off:
///
/// 1. **Summarise** (sequential, cheap): per source type, pick the `k`
///    longest-running victims and aggregate what draining them frees.
/// 2. **Enumerate + prune** (sequential, O(types² · apps)): walk the
///    `(victim set, cheaper type)` pairs in the historical nested-loop
///    order; with [`ReplaceOpts::prune`], drop pairs whose
///    [`spread_makespan_floor`]-based lower bound cannot beat the strict
///    commit test — they could never be selected, so the winner is
///    unchanged.
/// 3. **Generate + score** (parallel): synthesise each surviving pair's
///    LPT rows on the worker pool, merge candidates back in enumeration
///    order, and score through [`eval_deltas_chunked`].
///
/// Cooperative cancellation is polled in every phase (between victim
/// groups, between generated candidates, between scoring chunks); a
/// cancelled call abandons the round before anything is committed and
/// leaves the arena untouched, so the caller's stored best plan remains
/// the result.
pub fn replace_arena_opts(
    sys: &System,
    arena: &mut PlanArena,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
    cancel: &CancelToken,
    opts: &ReplaceOpts<'_>,
) -> bool {
    if arena.is_empty() || k == 0 {
        return false;
    }
    let before = arena.score(sys);
    let remaining = (budget - before.cost).max(0.0);

    // Phase 1: one summary per source type present in the plan.
    let execs: Vec<f64> = (0..arena.n_vms()).map(|p| arena.exec_at(sys, p)).collect();
    let mut present: Vec<bool> = vec![false; sys.n_types()];
    for pos in 0..arena.n_vms() {
        present[arena.it_at(pos).index()] = true;
    }
    let mut groups: Vec<VictimGroup> = Vec::new();
    for (src_idx, src_present) in present.iter().enumerate() {
        if cancel.is_cancelled() {
            return false; // abandon the round, arena untouched
        }
        if !src_present {
            continue;
        }
        let src_it = sys.instance_types[src_idx].id;
        // k most expensive (longest-running) VMs of the source type.
        let mut victims: Vec<usize> =
            (0..arena.n_vms()).filter(|&p| arena.it_at(p) == src_it).collect();
        victims.sort_by(|&a, &b| execs[b].total_cmp(&execs[a]));
        victims.truncate(k);
        if victims.is_empty() {
            continue;
        }
        let freed: f64 = victims.iter().map(|&p| arena.cost_at(sys, p)).sum();
        let drained: Vec<TaskId> = victims
            .iter()
            .flat_map(|&p| arena.tasks_at(p).iter().copied())
            .collect();
        let mut is_victim = vec![false; arena.n_vms()];
        for &v in &victims {
            is_victim[v] = true;
        }
        let mut drained_agg = vec![0.0f64; sys.n_apps()];
        let mut drained_max = vec![0.0f64; sys.n_apps()];
        for &t in &drained {
            let task = sys.task(t);
            let m = task.app.index();
            drained_agg[m] += task.size;
            drained_max[m] = drained_max[m].max(task.size);
        }
        let surviving_max_exec = (0..arena.n_vms())
            .filter(|&p| !is_victim[p] && !arena.is_empty_at(p))
            .map(|p| execs[p])
            .fold(0.0f64, f64::max);
        groups.push(VictimGroup {
            victims,
            is_victim,
            drained,
            drained_agg,
            drained_max,
            freed,
            src_rate: sys.rate(src_it),
            surviving_max_exec,
        });
    }

    // Phase 2: enumerate (victim set × cheaper type) pairs in the
    // historical nested-loop order; prune the dominated ones before any
    // LPT synthesis is paid for them.
    struct Pair<'g> {
        group: &'g VictimGroup,
        cheap: InstanceTypeId,
        n_new: usize,
    }
    let mut pairs: Vec<Pair<'_>> = Vec::new();
    let mut enumerated = 0u64;
    let mut pruned = 0u64;
    for g in &groups {
        for cheap in &sys.instance_types {
            if cheap.cost_per_hour >= g.src_rate {
                continue; // only strictly cheaper replacements
            }
            let n_new = ((g.freed + remaining) / cheap.cost_per_hour).floor() as usize;
            if n_new == 0 {
                continue;
            }
            enumerated += 1;
            if opts.prune {
                let lb = g.surviving_max_exec.max(spread_makespan_floor(
                    sys,
                    &g.drained_agg,
                    &g.drained_max,
                    cheap.id,
                    n_new,
                ));
                // Threshold-exact: the commit test demands
                // `makespan < before - 1e-9`, so a candidate whose lower
                // bound already sits at or above that line can never be
                // selected.  The extra 1e-6 margin keeps the bound
                // conservative against summation-order float noise
                // (the bound's fold order differs from the scorer's) —
                // it only ever *weakens* pruning, never the winner.
                if lb - 1e-6 >= before.makespan - 1e-9 {
                    pruned += 1;
                    continue;
                }
            }
            pairs.push(Pair { group: g, cheap: cheap.id, n_new });
        }
    }
    if let Some(p) = opts.probe {
        p.enumerated.fetch_add(enumerated, Ordering::Relaxed);
        p.pruned.fetch_add(pruned, Ordering::Relaxed);
    }
    if pairs.is_empty() {
        return false;
    }

    // Phase 3: build each surviving pair's candidate — surviving VMs as
    // borrowed arena rows (in plan order; empty survivors score as
    // dropped) + the new VMs' synthesised LPT rows — on the worker pool,
    // merged back in pair order, then chunk-score.  Each candidate is a
    // pure function of the shared immutable arena, so the batch is
    // identical to the sequential enumeration at any thread count.
    let shared_arena: &PlanArena = arena;
    let built = parallel_map(opts.threads, pairs.len(), |i| {
        if cancel.is_cancelled() {
            return None; // this pair abandoned; the round follows suit
        }
        let pair = &pairs[i];
        let g = pair.group;
        let mut cand = DeltaCandidate::default();
        for pos in 0..shared_arena.n_vms() {
            if g.is_victim[pos] || shared_arena.is_empty_at(pos) {
                continue;
            }
            let it = shared_arena.it_at(pos);
            cand.push_row(shared_arena.agg_at(pos), sys.perf.row(it), sys.rate(it));
        }
        if let Some(p) = opts.probe {
            p.synth_calls.fetch_add(1, Ordering::Relaxed);
        }
        let perf_new = sys.perf.row(pair.cheap);
        let rate_new = sys.rate(pair.cheap);
        for agg in lpt_agg_rows(sys, g.drained.clone(), pair.cheap, pair.n_new) {
            cand.push_synth(agg, perf_new, rate_new);
        }
        Some(cand)
    });
    let mut batch = DeltaBatch::new(sys);
    for cand in built {
        match cand {
            Some(c) => batch.push(c),
            None => return false, // cancelled mid-generation, arena untouched
        }
    }
    let Some(scores) = eval_deltas_chunked(evaluator, &batch, opts.threads, cancel) else {
        return false; // cancelled mid-scoring, arena untouched
    };
    drop(batch); // release the borrows on the arena before mutating it

    // Commit the best feasible candidate that strictly reduces exec time.
    let mut best: Option<(usize, f64)> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.cost <= budget + 1e-9 && s.makespan < before.makespan - 1e-9
            && best.as_ref().is_none_or(|(_, m)| s.makespan < *m) {
                best = Some((i, s.makespan));
            }
    }
    let Some((win, _)) = best else {
        return false;
    };

    // Apply the winning swap to the arena in place; freed victim slots
    // recycle into the new VMs via the free list.
    let Swap { victims, cheap, n_new } = {
        let w = &pairs[win];
        Swap { victims: w.group.victims.clone(), cheap: w.cheap, n_new: w.n_new }
    };
    drop(pairs);
    let mut drained = Vec::new();
    for &v in &victims {
        drained.extend(arena.drain_tasks(v));
    }
    arena.remove_vms(&victims);
    let new_ids: Vec<usize> = (0..n_new).map(|_| arena.add_vm(cheap)).collect();
    lpt_spread(sys, arena, drained, &new_ids);
    arena.drop_empty_vms();
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeEvaluator;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    /// The paper's own Sec. IV-G example: it_1 = ($2, 8 s/u), it_2 =
    /// ($1, 10 s/u), 10 tasks of size 1, budget $2.  One it_1 VM takes
    /// 80 s; two it_2 VMs take 50 s.  REPLACE must find the swap.
    fn paper_example() -> (System, Plan) {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0; 10])
            .instance_type("exp", 2.0, vec![8.0])
            .instance_type("cheap", 1.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        for t in 0..10 {
            plan.vms[v].push_task(&sys, TaskId(t));
        }
        (sys, plan)
    }

    #[test]
    fn paper_example_swap_found() {
        let (sys, mut plan) = paper_example();
        assert_eq!(plan.score(&sys).makespan, 80.0);
        let swapped = replace(&sys, &mut plan, 2.0, 1, &NativeEvaluator);
        assert!(swapped);
        let score = plan.score(&sys);
        assert_eq!(plan.vm_mix(&sys), vec![0, 2]);
        assert_eq!(score.makespan, 50.0);
        assert!(score.cost <= 2.0);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn no_swap_when_budget_too_tight() {
        let (sys, mut plan) = paper_example();
        // Budget 1: freed cost 2 + remaining(-1 -> 0) buys 2 cheap VMs but
        // the resulting cost 2 > budget 1 -> reject.
        assert!(!replace(&sys, &mut plan, 1.0, 1, &NativeEvaluator));
        assert_eq!(plan.vm_mix(&sys), vec![1, 0]);
    }

    #[test]
    fn no_swap_when_cheaper_is_not_faster() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0; 4])
            .instance_type("exp", 2.0, vec![8.0])
            .instance_type("cheap", 1.0, vec![100.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        for t in 0..4 {
            plan.vms[v].push_task(&sys, TaskId(t));
        }
        assert!(!replace(&sys, &mut plan, 2.0, 1, &NativeEvaluator));
    }

    #[test]
    fn k_zero_or_empty_plan_is_noop() {
        let (sys, mut plan) = paper_example();
        assert!(!replace(&sys, &mut plan, 2.0, 0, &NativeEvaluator));
        let mut empty = Plan::new();
        assert!(!replace(&sys, &mut empty, 2.0, 1, &NativeEvaluator));
    }

    #[test]
    fn arena_level_entry_commits_in_place() {
        let (sys, plan) = paper_example();
        let mut arena = PlanArena::from_plan(&sys, &plan);
        let swapped = replace_arena(
            &sys,
            &mut arena,
            2.0,
            1,
            &NativeEvaluator,
            &CancelToken::default(),
        );
        assert!(swapped);
        let out = arena.to_plan();
        assert_eq!(out.vm_mix(&sys), vec![0, 2]);
        assert_eq!(out.score(&sys).makespan, 50.0);
        assert!(out.validate_partition(&sys).is_ok());
    }

    #[test]
    fn lpt_agg_rows_mirrors_materialised_spread() {
        // Two apps, uneven sizes: simulate the spread and materialise it,
        // then compare the cached aggregations float for float.
        let sys = SystemBuilder::new()
            .app("a1", vec![5.0, 1.0, 3.0, 2.0])
            .app("a2", vec![4.0, 4.0, 1.0])
            .instance_type("x", 2.0, vec![7.0, 9.0])
            .build()
            .unwrap();
        let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
        let n_new = 3;
        let rows = lpt_agg_rows(&sys, tasks.clone(), InstanceTypeId(0), n_new);

        let mut arena = PlanArena::from_plan(&sys, &Plan::new());
        let ids: Vec<usize> = (0..n_new).map(|_| arena.add_vm(InstanceTypeId(0))).collect();
        lpt_spread(&sys, &mut arena, tasks, &ids);
        arena.drop_empty_vms();
        let plan = arena.to_plan();
        assert_eq!(rows.len(), plan.n_vms());
        for (row, vm) in rows.iter().zip(&plan.vms) {
            assert_eq!(row.as_slice(), vm.agg_sizes());
        }
    }
}
