//! Sec. IV-G `REPLACE`: swap expensive VMs for more, cheaper ones.
//!
//! Fewer fast-but-expensive VMs can lose to many moderate-but-cheap ones
//! (the paper's it_1-vs-it_2 example).  REPLACE picks `k` VMs of one
//! instance type, frees their billed cost, buys as many VMs of a cheaper
//! type as the freed cost plus any remaining budget affords (one-hour
//! price assumption), re-assigns the victims' tasks onto the new VMs only,
//! and commits the swap iff the budget still holds and the overall
//! execution time strictly drops.
//!
//! **Zero-clone delta batching over arena rows.**  Candidate swaps are
//! scored without materialising candidate plans: because a plan's score
//! depends on its assignment only through each VM's per-application
//! aggregated sizes (eq. 5 is linear in task size), a candidate is fully
//! described by the surviving VMs' aggregation rows — *borrowed* straight
//! out of [`PlanArena`]'s contiguous slot-major storage — plus `n_new`
//! synthesised rows for the replacement VMs (an LPT spread over
//! aggregated sizes, no `TaskId` routing).  All `(source type, cheaper
//! type)` alternatives form one [`DeltaBatch`] scored **in one evaluator
//! call** — this is the planner hot path that the AOT-compiled XLA
//! artifact accelerates in the coordinator.  Only the winning swap is
//! materialised, by mutating the arena in place (freed slots recycle via
//! the arena's free list; no `Vec<Vm>` shifting); the rejected candidates
//! never allocate more than their synthesised rows.  The `perf_parity`
//! and `arena_parity` integration tests pin this path bit-for-bit
//! against the historical clone-per-candidate implementation.

use crate::eval::{DeltaBatch, DeltaCandidate, PlanArena, PlanEvaluator};
use crate::model::{InstanceTypeId, Plan, System, TaskId};
use crate::util::CancelToken;

/// Evenly distribute `tasks` over the (same-typed) new VMs: longest
/// processing time first onto the least-loaded VM.  The paper's Sec. IV-G
/// example states "tasks are evenly distributed to both VMs"; LPT is the
/// standard way to realise that for identical machines.
fn lpt_spread(sys: &System, arena: &mut PlanArena, mut tasks: Vec<TaskId>, vms: &[usize]) {
    let it = arena.it_at(vms[0]);
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    for t in tasks {
        let dst = *vms
            .iter()
            .min_by(|&&a, &&b| arena.work_at(a).total_cmp(&arena.work_at(b)))
            .expect("at least one new VM");
        arena.push_task(sys, dst, t);
    }
}

/// Simulate [`lpt_spread`] over `n_new` fresh VMs of type `it` without an
/// arena: same sort, same first-minimum destination choice, same
/// accumulation order as `Vm::push_task`, so the resulting per-VM
/// aggregated sizes are float-for-float what the materialised spread
/// would cache.  Returns one aggregation row per new VM that received at
/// least one task (empty new VMs would be removed by `drop_empty_vms`).
fn lpt_agg_rows(
    sys: &System,
    mut tasks: Vec<TaskId>,
    it: InstanceTypeId,
    n_new: usize,
) -> Vec<Vec<f64>> {
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    let mut work = vec![0.0f64; n_new];
    let mut agg = vec![vec![0.0f64; sys.n_apps()]; n_new];
    let mut used = vec![false; n_new];
    for t in tasks {
        let dst = (0..n_new)
            .min_by(|&a, &b| work[a].total_cmp(&work[b]))
            .expect("n_new > 0");
        work[dst] += sys.exec_time(it, t);
        let task = sys.task(t);
        agg[dst][task.app.index()] += task.size;
        used[dst] = true;
    }
    agg.into_iter()
        .zip(used)
        .filter_map(|(a, u)| u.then_some(a))
        .collect()
}

/// One candidate swap, described symbolically until (and unless) it wins.
struct Swap {
    victims: Vec<usize>,
    cheap: InstanceTypeId,
    n_new: usize,
}

/// Try one replacement round; commits at most one swap (the paper
/// considers "only one instance type at a time").  Returns true if a swap
/// was applied.
///
/// `Plan`-level wrapper around [`replace_arena`]; the store-back is
/// skipped when no swap committed.
pub fn replace(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
) -> bool {
    replace_cancellable(sys, plan, budget, k, evaluator, &CancelToken::default())
}

/// [`replace`] with a cooperative cancellation checkpoint (see
/// [`replace_arena`]).
pub fn replace_cancellable(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
    cancel: &CancelToken,
) -> bool {
    let mut arena = PlanArena::from_plan(sys, plan);
    let swapped = replace_arena(sys, &mut arena, budget, k, evaluator, cancel);
    if swapped {
        arena.store_plan(plan);
    }
    swapped
}

/// One replacement round on arena state, in place, with a cooperative
/// cancellation checkpoint in the candidate-enumeration loop: a cancelled
/// call abandons the round before the (batched) evaluator execution and
/// leaves the arena untouched, so the caller's stored best plan remains
/// the result.  Returns true if a swap was applied.
pub fn replace_arena(
    sys: &System,
    arena: &mut PlanArena,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
    cancel: &CancelToken,
) -> bool {
    if arena.is_empty() || k == 0 {
        return false;
    }
    let before = arena.score(sys);
    let remaining = (budget - before.cost).max(0.0);

    // Enumerate candidate swaps as deltas against the live arena state.
    let mut swaps: Vec<Swap> = Vec::new();
    let mut batch = DeltaBatch::new(sys);
    let mut present: Vec<bool> = vec![false; sys.n_types()];
    for pos in 0..arena.n_vms() {
        present[arena.it_at(pos).index()] = true;
    }
    for (src_idx, src_present) in present.iter().enumerate() {
        if cancel.is_cancelled() {
            return false; // abandon the round, arena untouched
        }
        if !src_present {
            continue;
        }
        let src_it = sys.instance_types[src_idx].id;
        let src_rate = sys.rate(src_it);
        // k most expensive (longest-running) VMs of the source type.
        let mut victims: Vec<usize> =
            (0..arena.n_vms()).filter(|&p| arena.it_at(p) == src_it).collect();
        victims.sort_by(|&a, &b| arena.exec_at(sys, b).total_cmp(&arena.exec_at(sys, a)));
        victims.truncate(k);
        if victims.is_empty() {
            continue;
        }
        let freed: f64 = victims.iter().map(|&p| arena.cost_at(sys, p)).sum();
        // The tasks a materialised swap would drain, in drain order.
        let drained: Vec<TaskId> = victims
            .iter()
            .flat_map(|&p| arena.tasks_at(p).iter().copied())
            .collect();
        let mut is_victim = vec![false; arena.n_vms()];
        for &v in &victims {
            is_victim[v] = true;
        }

        for cheap in &sys.instance_types {
            if cheap.cost_per_hour >= src_rate {
                continue; // only strictly cheaper replacements
            }
            let n_new = ((freed + remaining) / cheap.cost_per_hour).floor() as usize;
            if n_new == 0 {
                continue;
            }
            // Candidate = surviving VMs (borrowed arena rows, in plan
            // order; empty survivors score as dropped) + the new VMs'
            // LPT rows.
            let mut cand = DeltaCandidate::default();
            for pos in 0..arena.n_vms() {
                if is_victim[pos] || arena.is_empty_at(pos) {
                    continue;
                }
                let it = arena.it_at(pos);
                cand.push_row(arena.agg_at(pos), sys.perf.row(it), sys.rate(it));
            }
            let perf_new = sys.perf.row(cheap.id);
            for agg in lpt_agg_rows(sys, drained.clone(), cheap.id, n_new) {
                cand.push_synth(agg, perf_new, cheap.cost_per_hour);
            }
            batch.push(cand);
            swaps.push(Swap { victims: victims.clone(), cheap: cheap.id, n_new });
        }
    }
    if swaps.is_empty() {
        return false;
    }

    // Batch-score all alternatives in one evaluator call.
    let scores = evaluator.eval_deltas(&batch);
    drop(batch); // release the borrows on the arena before mutating it

    // Commit the best feasible candidate that strictly reduces exec time.
    let mut best: Option<(usize, f64)> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.cost <= budget + 1e-9 && s.makespan < before.makespan - 1e-9
            && best.as_ref().is_none_or(|(_, m)| s.makespan < *m) {
                best = Some((i, s.makespan));
            }
    }
    let Some((win, _)) = best else {
        return false;
    };

    // Apply the winning swap to the arena in place; freed victim slots
    // recycle into the new VMs via the free list.
    let Swap { victims, cheap, n_new } = swaps.swap_remove(win);
    let mut drained = Vec::new();
    for &v in &victims {
        drained.extend(arena.drain_tasks(v));
    }
    arena.remove_vms(&victims);
    let new_ids: Vec<usize> = (0..n_new).map(|_| arena.add_vm(cheap)).collect();
    lpt_spread(sys, arena, drained, &new_ids);
    arena.drop_empty_vms();
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeEvaluator;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    /// The paper's own Sec. IV-G example: it_1 = ($2, 8 s/u), it_2 =
    /// ($1, 10 s/u), 10 tasks of size 1, budget $2.  One it_1 VM takes
    /// 80 s; two it_2 VMs take 50 s.  REPLACE must find the swap.
    fn paper_example() -> (System, Plan) {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0; 10])
            .instance_type("exp", 2.0, vec![8.0])
            .instance_type("cheap", 1.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        for t in 0..10 {
            plan.vms[v].push_task(&sys, TaskId(t));
        }
        (sys, plan)
    }

    #[test]
    fn paper_example_swap_found() {
        let (sys, mut plan) = paper_example();
        assert_eq!(plan.score(&sys).makespan, 80.0);
        let swapped = replace(&sys, &mut plan, 2.0, 1, &NativeEvaluator);
        assert!(swapped);
        let score = plan.score(&sys);
        assert_eq!(plan.vm_mix(&sys), vec![0, 2]);
        assert_eq!(score.makespan, 50.0);
        assert!(score.cost <= 2.0);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn no_swap_when_budget_too_tight() {
        let (sys, mut plan) = paper_example();
        // Budget 1: freed cost 2 + remaining(-1 -> 0) buys 2 cheap VMs but
        // the resulting cost 2 > budget 1 -> reject.
        assert!(!replace(&sys, &mut plan, 1.0, 1, &NativeEvaluator));
        assert_eq!(plan.vm_mix(&sys), vec![1, 0]);
    }

    #[test]
    fn no_swap_when_cheaper_is_not_faster() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0; 4])
            .instance_type("exp", 2.0, vec![8.0])
            .instance_type("cheap", 1.0, vec![100.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        for t in 0..4 {
            plan.vms[v].push_task(&sys, TaskId(t));
        }
        assert!(!replace(&sys, &mut plan, 2.0, 1, &NativeEvaluator));
    }

    #[test]
    fn k_zero_or_empty_plan_is_noop() {
        let (sys, mut plan) = paper_example();
        assert!(!replace(&sys, &mut plan, 2.0, 0, &NativeEvaluator));
        let mut empty = Plan::new();
        assert!(!replace(&sys, &mut empty, 2.0, 1, &NativeEvaluator));
    }

    #[test]
    fn arena_level_entry_commits_in_place() {
        let (sys, plan) = paper_example();
        let mut arena = PlanArena::from_plan(&sys, &plan);
        let swapped = replace_arena(
            &sys,
            &mut arena,
            2.0,
            1,
            &NativeEvaluator,
            &CancelToken::default(),
        );
        assert!(swapped);
        let out = arena.to_plan();
        assert_eq!(out.vm_mix(&sys), vec![0, 2]);
        assert_eq!(out.score(&sys).makespan, 50.0);
        assert!(out.validate_partition(&sys).is_ok());
    }

    #[test]
    fn lpt_agg_rows_mirrors_materialised_spread() {
        // Two apps, uneven sizes: simulate the spread and materialise it,
        // then compare the cached aggregations float for float.
        let sys = SystemBuilder::new()
            .app("a1", vec![5.0, 1.0, 3.0, 2.0])
            .app("a2", vec![4.0, 4.0, 1.0])
            .instance_type("x", 2.0, vec![7.0, 9.0])
            .build()
            .unwrap();
        let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
        let n_new = 3;
        let rows = lpt_agg_rows(&sys, tasks.clone(), InstanceTypeId(0), n_new);

        let mut arena = PlanArena::from_plan(&sys, &Plan::new());
        let ids: Vec<usize> = (0..n_new).map(|_| arena.add_vm(InstanceTypeId(0))).collect();
        lpt_spread(&sys, &mut arena, tasks, &ids);
        arena.drop_empty_vms();
        let plan = arena.to_plan();
        assert_eq!(rows.len(), plan.n_vms());
        for (row, vm) in rows.iter().zip(&plan.vms) {
            assert_eq!(row.as_slice(), vm.agg_sizes());
        }
    }
}
