//! Sec. IV-G `REPLACE`: swap expensive VMs for more, cheaper ones.
//!
//! Fewer fast-but-expensive VMs can lose to many moderate-but-cheap ones
//! (the paper's it_1-vs-it_2 example).  REPLACE picks `k` VMs of one
//! instance type, frees their billed cost, buys as many VMs of a cheaper
//! type as the freed cost plus any remaining budget affords (one-hour
//! price assumption), re-assigns the victims' tasks onto the new VMs only,
//! and commits the swap iff the budget still holds and the overall
//! execution time strictly drops.
//!
//! All `(source type, cheaper type)` alternatives are materialised as
//! candidate plans and scored **in one batch** through the
//! [`PlanEvaluator`] — this is the planner hot path that the AOT-compiled
//! XLA artifact accelerates in the coordinator.

use crate::eval::PlanEvaluator;
use crate::model::{Plan, System, TaskId};

/// Evenly distribute `tasks` over the (same-typed) new VMs: longest
/// processing time first onto the least-loaded VM.  The paper's Sec. IV-G
/// example states "tasks are evenly distributed to both VMs"; LPT is the
/// standard way to realise that for identical machines.
fn lpt_spread(sys: &System, plan: &mut Plan, mut tasks: Vec<TaskId>, vms: &[usize]) {
    let it = plan.vms[vms[0]].it;
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    for t in tasks {
        let dst = *vms
            .iter()
            .min_by(|&&a, &&b| plan.vms[a].work().total_cmp(&plan.vms[b].work()))
            .expect("at least one new VM");
        plan.vms[dst].push_task(sys, t);
    }
}

/// Try one replacement round; commits at most one swap (the paper
/// considers "only one instance type at a time").  Returns true if a swap
/// was applied.
pub fn replace(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
) -> bool {
    if plan.is_empty() || k == 0 {
        return false;
    }
    let before = plan.score(sys);
    let remaining = (budget - before.cost).max(0.0);

    // Enumerate candidate swaps.
    let mut candidates: Vec<Plan> = Vec::new();
    let mut present: Vec<bool> = vec![false; sys.n_types()];
    for vm in &plan.vms {
        present[vm.it.index()] = true;
    }
    for (src_idx, src_present) in present.iter().enumerate() {
        if !src_present {
            continue;
        }
        let src_it = sys.instance_types[src_idx].id;
        let src_rate = sys.rate(src_it);
        // k most expensive (longest-running) VMs of the source type.
        let mut victims: Vec<usize> = plan
            .vms
            .iter()
            .enumerate()
            .filter(|(_, vm)| vm.it == src_it)
            .map(|(i, _)| i)
            .collect();
        victims.sort_by(|&a, &b| plan.vms[b].exec(sys).total_cmp(&plan.vms[a].exec(sys)));
        victims.truncate(k);
        if victims.is_empty() {
            continue;
        }
        let freed: f64 = victims.iter().map(|&i| plan.vms[i].cost(sys)).sum();

        for cheap in &sys.instance_types {
            if cheap.cost_per_hour >= src_rate {
                continue; // only strictly cheaper replacements
            }
            let n_new = ((freed + remaining) / cheap.cost_per_hour).floor() as usize;
            if n_new == 0 {
                continue;
            }
            // Build the candidate: drop victims, add n_new cheap VMs,
            // route the drained tasks onto the new VMs only.
            let mut cand = plan.clone();
            let mut drained = Vec::new();
            for &v in &victims {
                drained.extend(cand.vms[v].drain_tasks());
            }
            // Remove in descending index order to keep indices stable.
            let mut vs = victims.clone();
            vs.sort_unstable_by(|a, b| b.cmp(a));
            for v in vs {
                cand.remove_vm(v);
            }
            let new_ids: Vec<usize> = (0..n_new).map(|_| cand.add_vm(sys, cheap.id)).collect();
            lpt_spread(sys, &mut cand, drained, &new_ids);
            cand.drop_empty_vms();
            candidates.push(cand);
        }
    }
    if candidates.is_empty() {
        return false;
    }

    // Batch-score all alternatives in one evaluator call.
    let refs: Vec<&Plan> = candidates.iter().collect();
    let scores = evaluator.eval_plans(sys, &refs);

    // Commit the best feasible candidate that strictly reduces exec time.
    let mut best: Option<(usize, f64)> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.cost <= budget + 1e-9 && s.makespan < before.makespan - 1e-9
            && best.as_ref().is_none_or(|(_, m)| s.makespan < *m) {
                best = Some((i, s.makespan));
            }
    }
    match best {
        Some((i, _)) => {
            *plan = candidates.swap_remove(i);
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::NativeEvaluator;
    use crate::model::{InstanceTypeId, SystemBuilder, TaskId};

    /// The paper's own Sec. IV-G example: it_1 = ($2, 8 s/u), it_2 =
    /// ($1, 10 s/u), 10 tasks of size 1, budget $2.  One it_1 VM takes
    /// 80 s; two it_2 VMs take 50 s.  REPLACE must find the swap.
    fn paper_example() -> (System, Plan) {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0; 10])
            .instance_type("exp", 2.0, vec![8.0])
            .instance_type("cheap", 1.0, vec![10.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        for t in 0..10 {
            plan.vms[v].push_task(&sys, TaskId(t));
        }
        (sys, plan)
    }

    #[test]
    fn paper_example_swap_found() {
        let (sys, mut plan) = paper_example();
        assert_eq!(plan.score(&sys).makespan, 80.0);
        let swapped = replace(&sys, &mut plan, 2.0, 1, &NativeEvaluator);
        assert!(swapped);
        let score = plan.score(&sys);
        assert_eq!(plan.vm_mix(&sys), vec![0, 2]);
        assert_eq!(score.makespan, 50.0);
        assert!(score.cost <= 2.0);
        assert!(plan.validate_partition(&sys).is_ok());
    }

    #[test]
    fn no_swap_when_budget_too_tight() {
        let (sys, mut plan) = paper_example();
        // Budget 1: freed cost 2 + remaining(-1 -> 0) buys 2 cheap VMs but
        // the resulting cost 2 > budget 1 -> reject.
        assert!(!replace(&sys, &mut plan, 1.0, 1, &NativeEvaluator));
        assert_eq!(plan.vm_mix(&sys), vec![1, 0]);
    }

    #[test]
    fn no_swap_when_cheaper_is_not_faster() {
        let sys = SystemBuilder::new()
            .app("a", vec![1.0; 4])
            .instance_type("exp", 2.0, vec![8.0])
            .instance_type("cheap", 1.0, vec![100.0])
            .build()
            .unwrap();
        let mut plan = Plan::new();
        let v = plan.add_vm(&sys, InstanceTypeId(0));
        for t in 0..4 {
            plan.vms[v].push_task(&sys, TaskId(t));
        }
        assert!(!replace(&sys, &mut plan, 2.0, 1, &NativeEvaluator));
    }

    #[test]
    fn k_zero_or_empty_plan_is_noop() {
        let (sys, mut plan) = paper_example();
        assert!(!replace(&sys, &mut plan, 2.0, 0, &NativeEvaluator));
        let mut empty = Plan::new();
        assert!(!replace(&sys, &mut empty, 2.0, 1, &NativeEvaluator));
    }
}
