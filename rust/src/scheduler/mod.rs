//! Scheduling policies: the paper's Section IV heuristic planner, the
//! Section V baselines, the Section VI extensions — all behind one
//! uniform solver API.
//!
//! **Entry point:** the [`policy`] module. Resolve a policy by name from
//! the [`PolicyRegistry`], describe the problem with a [`SolveRequest`],
//! and get a [`SolveOutcome`] back:
//!
//! ```text
//! let registry = PolicyRegistry::builtin();
//! let outcome  = registry.solve("budget-heuristic", &sys, &SolveRequest::new(80.0))?;
//! ```
//!
//! | module            | role |
//! |-------------------|------|
//! | [`policy`]        | `Policy` trait, `SolveRequest`/`SolveOutcome`, name registry |
//! | [`find`]          | Alg. 1 `FIND`: the fixed-point iteration tying the phases together |
//! | [`assign`]        | paper `ASSIGN`: route tasks to VMs by (no-cost-increase, task speed, VM load) |
//! | [`balance`]       | paper `BALANCE`: even out VM finish times without raising makespan/cost (arena inner loop) |
//! | [`initial`]       | paper `INITIAL`: per-app best-type pools sized by the whole budget |
//! | [`reduce`]        | paper `REDUCE`: dismantle whole VMs (local/global) until the budget holds |
//! | [`add`]           | paper `ADD`: spend remaining budget on the best-performing affordable type |
//! | [`split`]         | paper `SPLIT`: keep VM run times under one billed hour (paper's *KEEP*) |
//! | [`replace`]       | paper `REPLACE`: swap expensive VMs for more cheaper ones (zero-clone delta batching over arena rows) |
//! | [`baselines`]     | Sec. V-A baselines MI and MP |
//! | [`multistart`]    | GRASP-style perturbed restarts of FIND (parallel via `util::parallel`) |
//! | [`deadline`]      | Sec. VI: deadline-constrained cost minimisation |
//! | [`dynamic`]       | Sec. VI: residual re-planning mid-execution |
//! | [`nonclairvoyant`]| Sec. VI: planning under estimated sizes + online dispatch |
//!
//! Registered policy names: `"budget-heuristic"`, `"mi"`, `"mp"`,
//! `"multistart"`, `"deadline"`, `"dynamic"`, `"nonclairvoyant"` (plus
//! aliases such as `"heuristic"`; see [`policy::canonical_name`]).
//!
//! The per-policy entry points (`Planner::find`, `find_multistart`,
//! `minimise_individual`, ...) remain as the underlying implementations
//! and keep compiling for existing callers, but new code — and anything
//! that wants to be policy-generic — should go through the registry.
//!
//! **Hot-loop state:** the phases that dominate solve time (BALANCE's
//! move search, REPLACE's swap scoring, FIND's accept test) run on the
//! struct-of-arrays [`crate::eval::PlanArena`] — FIND keeps one arena
//! live across phases and iterations and materialises back to
//! [`crate::model::Plan`] only when a phase changed something.  The
//! arena-level entry points ([`balance_arena`](balance::balance_arena),
//! [`replace_arena`](replace::replace_arena)) are exported for callers
//! that already hold arena state; the plain [`balance`]/[`replace`]
//! wrappers keep the `Plan`-level signatures.
//!
//! **Parallelism model:** every parallel path in the scheduler is
//! *deterministic* — same inputs, same plan, bit for bit, at any thread
//! count (pinned by the `parallel_parity` suite).  Two layers exist and
//! exactly one fans out at a time:
//!
//! * **inter-solve** — independent planner runs: multistart restarts and
//!   deadline bisection probes over [`crate::util::parallel_map`];
//! * **intra-solve** — inside one FIND ([`Planner::with_threads`]):
//!   REPLACE partitions candidate generation across workers and scores
//!   the merged batch through
//!   [`eval_deltas_chunked`](crate::eval::eval_deltas_chunked), BALANCE
//!   chunks its move search over the makespan VM's tasks.
//!
//! When an outer layer runs on more than one worker, the inner layer is
//! forced sequential ([`crate::util::nested_inner_threads`]) so thread
//! counts never multiply.  REPLACE additionally prunes dominated
//! candidates with the [`crate::analysis::spread_makespan_floor`] lower
//! bound before synthesising their LPT rows
//! ([`replace::ReplaceOpts::prune`]) — threshold-exact, so the winner
//! (and the plan) is unchanged.

pub mod add;
pub mod assign;
pub mod balance;
pub mod baselines;
pub mod deadline;
pub mod dynamic;
pub mod find;
pub mod initial;
pub mod multistart;
pub mod nonclairvoyant;
pub mod policy;
pub mod reduce;
pub mod replace;
pub mod split;

pub use add::add_vms;
pub use assign::{assign, assign_restricted};
pub use balance::{balance, balance_arena, balance_arena_threaded};
pub use baselines::{maximise_parallelism, minimise_individual};
pub use find::{FindReport, Planner, PlannerConfig};
pub use initial::initial;
pub use multistart::{find_multistart, MultiStartConfig};
pub use policy::{
    canonical_name, legacy_name, BudgetHeuristic, DeadlineSearch, DynamicReplan,
    MaximiseParallelism, MinimiseIndividual, MultiStart, NonClairvoyant, Policy, PolicyRegistry,
    SolveOutcome, SolveRequest, UnknownPolicy, BUILTIN_POLICIES,
};
pub use reduce::{reduce, ReduceMode};
pub use replace::{
    replace, replace_arena, replace_arena_opts, replace_cancellable, ReplaceOpts, ReplaceProbe,
};
pub use split::split;
