//! The paper's Section IV heuristic planner and Section V baselines.
//!
//! The planner is decomposed exactly as the paper presents it:
//!
//! | paper fn  | module       | purpose |
//! |-----------|--------------|---------|
//! | `ASSIGN`  | [`assign`]   | route tasks to VMs by (no-cost-increase, task speed, VM load) |
//! | `BALANCE` | [`balance`]  | even out VM finish times without raising makespan/cost |
//! | `INITIAL` | [`initial`]  | per-app best-type pools sized by the whole budget |
//! | `REDUCE`  | [`reduce`]   | dismantle whole VMs (local/global) until the budget holds |
//! | `ADD`     | [`add`]      | spend remaining budget on the best-performing affordable type |
//! | `SPLIT`   | [`split`]    | keep VM run times under one billed hour (paper's *KEEP*) |
//! | `REPLACE` | [`replace`]  | swap expensive VMs for more cheaper ones when it pays off |
//! | Alg. 1    | [`find`]     | the fixed-point iteration tying the phases together |
//!
//! Baselines (Sec. V-A): [`baselines::minimise_individual`] (MI) and
//! [`baselines::maximise_parallelism`] (MP).
//!
//! Future-work extensions (Sec. VI): [`deadline`] (deadline-constrained
//! cost minimisation), [`dynamic`] (re-planning mid-execution) and
//! [`nonclairvoyant`] (unknown task sizes).

pub mod add;
pub mod assign;
pub mod balance;
pub mod baselines;
pub mod deadline;
pub mod dynamic;
pub mod find;
pub mod initial;
pub mod multistart;
pub mod nonclairvoyant;
pub mod reduce;
pub mod replace;
pub mod split;

pub use add::add_vms;
pub use assign::{assign, assign_restricted};
pub use balance::balance;
pub use baselines::{maximise_parallelism, minimise_individual};
pub use find::{FindReport, Planner, PlannerConfig};
pub use initial::initial;
pub use multistart::{find_multistart, MultiStartConfig};
pub use reduce::{reduce, ReduceMode};
pub use replace::replace;
pub use split::split;
