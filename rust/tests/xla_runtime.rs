//! Differential tests: the PJRT-backed evaluators must agree with the
//! exact rust-native implementations on the same inputs.
//!
//! These tests require `make artifacts` to have run (they are part of
//! `make test`); they skip silently when artifacts are absent so plain
//! `cargo test` works in a fresh checkout.

use botsched::cloudsim::{sample_runs, NoiseModel};
use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::model::{BillingPolicy, SystemBuilder};
use botsched::runtime::{ArtifactMeta, XlaEvaluator, XlaPerfEstimator};
use botsched::scheduler::{maximise_parallelism, minimise_individual, Planner};
use botsched::workload::paper::{table1_system, BUDGETS};
use botsched::workload::{WorkloadGenerator, WorkloadSpec};

fn xla() -> Option<XlaEvaluator> {
    let meta = ArtifactMeta::load().ok()?;
    Some(XlaEvaluator::load_with(meta).expect("artifact compiles on PJRT CPU"))
}

fn assert_close(a: f64, b: f64, rel: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1.0);
    assert!((a - b).abs() / denom < rel, "{what}: xla {a} vs native {b}");
}

#[test]
fn xla_matches_native_on_paper_plans() {
    let Some(xla) = xla() else { return };
    let sys = table1_system(42.0);
    for &b in BUDGETS {
        for plan in [
            Planner::new(&sys).find(b).plan,
            minimise_individual(&sys, b),
            maximise_parallelism(&sys, b),
        ] {
            let n = NativeEvaluator.eval_plan(&sys, &plan);
            let x = xla.eval_plan(&sys, &plan);
            assert_close(x.makespan, n.makespan, 1e-4, "makespan");
            assert_close(x.cost, n.cost, 1e-6, "cost");
        }
    }
}

#[test]
fn xla_matches_native_on_random_systems() {
    let Some(xla) = xla() else { return };
    let mut gen = WorkloadGenerator::new(123);
    for seed in 0..6u64 {
        let spec = WorkloadSpec {
            n_apps: 1 + (seed as usize % 5),
            n_types: 2 + (seed as usize % 4),
            tasks_per_app: 40,
            overhead: (seed as f64) * 17.0,
            ..Default::default()
        };
        let sys = gen.system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.5);
        let plans = [
            Planner::new(&sys).find(budget).plan,
            minimise_individual(&sys, budget),
            maximise_parallelism(&sys, budget),
        ];
        let refs: Vec<_> = plans.iter().collect();
        let native = NativeEvaluator.eval_plans(&sys, &refs);
        let xs = xla.eval_plans(&sys, &refs);
        for (x, n) in xs.iter().zip(&native) {
            assert_close(x.makespan, n.makespan, 1e-4, "makespan");
            assert_close(x.cost, n.cost, 1e-5, "cost");
        }
    }
}

#[test]
fn xla_batches_larger_than_k() {
    let Some(xla) = xla() else { return };
    let sys = table1_system(0.0);
    // 150 candidates > K=64 forces multi-chunk execution.
    let plans: Vec<_> = (0..150)
        .map(|i| maximise_parallelism(&sys, 40.0 + (i % 10) as f64 * 5.0))
        .collect();
    let refs: Vec<_> = plans.iter().collect();
    let native = NativeEvaluator.eval_plans(&sys, &refs);
    let xs = xla.eval_plans(&sys, &refs);
    assert_eq!(xs.len(), 150);
    for (x, n) in xs.iter().zip(&native) {
        assert_close(x.makespan, n.makespan, 1e-4, "makespan");
        assert_close(x.cost, n.cost, 1e-5, "cost");
    }
}

#[test]
fn per_second_billing_falls_back_to_native() {
    let Some(xla) = xla() else { return };
    let sys = SystemBuilder::new()
        .app("a", vec![100.0; 8])
        .instance_type("x", 5.0, vec![10.0])
        .billing(BillingPolicy::PerSecond)
        .build()
        .unwrap();
    let plan = maximise_parallelism(&sys, 20.0);
    let n = NativeEvaluator.eval_plan(&sys, &plan);
    let x = xla.eval_plan(&sys, &plan);
    assert_close(x.cost, n.cost, 1e-9, "fractional cost must be exact (native path)");
}

#[test]
fn planner_with_xla_evaluator_reproduces_native_decisions() {
    let Some(xla) = xla() else { return };
    let sys = table1_system(0.0);
    for &b in &[45.0, 65.0, 85.0] {
        let with_xla = Planner::with_evaluator(&sys, &xla).find(b);
        let with_native = Planner::new(&sys).find(b);
        // f32 scoring could in principle flip a tie; on this workload the
        // decisions must coincide.
        assert_close(with_xla.score.makespan, with_native.score.makespan, 1e-3, "makespan");
        assert_close(with_xla.score.cost, with_native.score.cost, 1e-3, "cost");
        assert!(with_xla.plan.validate_partition(&sys).is_ok());
    }
}

#[test]
fn xla_perf_estimator_matches_native_formula() {
    let Ok(meta) = ArtifactMeta::load() else { return };
    let est = XlaPerfEstimator::load_with(meta).expect("estimator compiles");
    let sys = table1_system(0.0);
    let obs = sample_runs(&sys, 20, &NoiseModel::jitter(0.05), 9);
    let prior = vec![15.0f64; 12];
    let native = botsched::cloudsim::sampling::estimate_perf_native(&sys, &obs, &prior, 1.0);
    let xla = est.estimate(&sys, &obs, &prior, 1.0).expect("estimation runs");
    assert_eq!(xla.len(), 12);
    for (x, n) in xla.iter().zip(&native) {
        assert!((x - n).abs() / n < 1e-4, "xla {x} vs native {n}");
    }
}

#[test]
fn xla_estimator_rejects_oversize_inputs() {
    let Ok(meta) = ArtifactMeta::load() else { return };
    let s_max = meta.s;
    let est = XlaPerfEstimator::load_with(meta).expect("estimator compiles");
    let sys = table1_system(0.0);
    let obs = sample_runs(&sys, s_max / 12 + 1, &NoiseModel::none(), 1);
    assert!(obs.len() > s_max);
    assert!(est.estimate(&sys, &obs, &[0.0; 12], 0.0).is_err());
}

#[test]
fn chunk_boundaries_route_correctly() {
    // Exercises the big/small artifact dispatch: 65 candidates = one
    // 64-chunk + one 1-tail (small exe), 9 = 8 + 1 (both small), 7 = one
    // small call. All must agree with native.
    let Some(xla) = xla() else { return };
    let sys = table1_system(12.0);
    let pool: Vec<_> = (0..8).map(|i| Planner::new(&sys).find(60.0 + i as f64 * 4.0).plan).collect();
    for n in [1usize, 7, 8, 9, 63, 64, 65, 129] {
        let refs: Vec<_> = (0..n).map(|i| &pool[i % pool.len()]).collect();
        let native = NativeEvaluator.eval_plans(&sys, &refs);
        let got = xla.eval_plans(&sys, &refs);
        assert_eq!(got.len(), n);
        for (i, (x, nv)) in got.iter().zip(&native).enumerate() {
            assert_close(x.makespan, nv.makespan, 1e-4, &format!("n={n} i={i} makespan"));
            assert_close(x.cost, nv.cost, 1e-5, &format!("n={n} i={i} cost"));
        }
    }
}

#[test]
fn oversize_vm_count_falls_back_to_native_per_candidate() {
    // A candidate with more VMs than the artifact's V must be scored
    // natively while its batch-mates still ride the artifact.
    let Some(xla) = xla() else { return };
    let sys = table1_system(0.0);
    let small_plan = Planner::new(&sys).find(70.0).plan;
    let mut huge_plan = botsched::model::Plan::new();
    for i in 0..200 {
        // 200 VMs > V=128.
        let v = huge_plan.add_vm(&sys, botsched::model::InstanceTypeId((i % 4) as u16));
        let _ = v;
    }
    for (slot, t) in sys.tasks().iter().enumerate() {
        huge_plan.vms[slot % 200].push_task(&sys, t.id);
    }
    let refs = vec![&small_plan, &huge_plan];
    let native = NativeEvaluator.eval_plans(&sys, &refs);
    let got = xla.eval_plans(&sys, &refs);
    for (x, n) in got.iter().zip(&native) {
        assert_close(x.makespan, n.makespan, 1e-4, "makespan");
        assert_close(x.cost, n.cost, 1e-5, "cost");
    }
}
