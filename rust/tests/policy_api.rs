//! Integration tests for the unified `Policy` API: the registry resolves
//! every built-in name (and rejects unknown ones), and the new entry
//! points are **bit-for-bit identical** to the legacy ones on the paper's
//! Table I workload — the refactor must not move a single float.

use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::model::Plan;
use botsched::scheduler::{
    find_multistart, maximise_parallelism, minimise_individual, MultiStartConfig, Planner,
    PolicyRegistry, SolveRequest, BUILTIN_POLICIES,
};
use botsched::workload::paper::{table1_system, BUDGETS};

/// Exact structural equality: same VMs in order, same instance types,
/// same task lists.
fn assert_plans_identical(context: &str, a: &Plan, b: &Plan) {
    assert_eq!(a.n_vms(), b.n_vms(), "{context}: VM count differs");
    for (i, (x, y)) in a.vms.iter().zip(&b.vms).enumerate() {
        assert_eq!(x.it, y.it, "{context}: vm{i} instance type differs");
        assert_eq!(x.tasks(), y.tasks(), "{context}: vm{i} task list differs");
    }
}

#[test]
fn registry_resolves_all_builtin_names_and_rejects_unknown() {
    let registry = PolicyRegistry::builtin();
    assert_eq!(registry.names(), BUILTIN_POLICIES);
    for &name in BUILTIN_POLICIES {
        assert!(registry.get(name).is_some(), "{name} must resolve");
    }
    for bad in ["", "Heuristic", "budget_heuristic", "magic"] {
        assert!(registry.get(bad).is_none(), "{bad:?} must not resolve");
    }
    let err = registry
        .solve("magic", &table1_system(0.0), &SolveRequest::new(80.0))
        .unwrap_err();
    assert!(err.to_string().contains("magic"));
}

#[test]
fn budget_heuristic_outcome_matches_legacy_planner_bit_for_bit() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    for &b in BUDGETS {
        let legacy = Planner::new(&sys).find(b);
        let out = registry
            .solve("budget-heuristic", &sys, &SolveRequest::new(b))
            .unwrap();
        assert_plans_identical(&format!("budget {b}"), &legacy.plan, &out.plan);
        assert_eq!(
            legacy.score.makespan.to_bits(),
            out.score.makespan.to_bits(),
            "budget {b}: makespan bits differ"
        );
        assert_eq!(
            legacy.score.cost.to_bits(),
            out.score.cost.to_bits(),
            "budget {b}: cost bits differ"
        );
        assert_eq!(legacy.feasible, out.feasible, "budget {b}");
        assert_eq!(legacy.iterations, out.iterations, "budget {b}");
    }
}

#[test]
fn baseline_outcomes_match_legacy_free_functions_bit_for_bit() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    for &b in BUDGETS {
        for (name, legacy) in [
            ("mi", minimise_individual(&sys, b)),
            ("mp", maximise_parallelism(&sys, b)),
        ] {
            let out = registry.solve(name, &sys, &SolveRequest::new(b)).unwrap();
            assert_plans_identical(&format!("{name} @ {b}"), &legacy, &out.plan);
            // Same scoring path as the policy (the evaluator): bit-exact.
            let score = NativeEvaluator.eval_plan(&sys, &legacy);
            assert_eq!(
                score.makespan.to_bits(),
                out.score.makespan.to_bits(),
                "{name} @ {b}: makespan bits differ"
            );
            assert_eq!(
                score.cost.to_bits(),
                out.score.cost.to_bits(),
                "{name} @ {b}: cost bits differ"
            );
            assert_eq!(score.satisfies(b), out.feasible, "{name} @ {b}");
            // And the plan's own arithmetic agrees to float tolerance.
            let direct = legacy.score(&sys);
            assert!((direct.makespan - out.score.makespan).abs() < 1e-9, "{name} @ {b}");
            assert!((direct.cost - out.score.cost).abs() < 1e-9, "{name} @ {b}");
        }
    }
}

#[test]
fn multistart_outcome_matches_legacy_entry_point() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    let req = SolveRequest::new(80.0).with_seed(9).with_starts(4);
    let legacy = find_multistart(
        &sys,
        80.0,
        &MultiStartConfig { n_starts: 4, seed: 9, ..Default::default() },
        &NativeEvaluator,
    );
    let out = registry.solve("multistart", &sys, &req).unwrap();
    assert_plans_identical("multistart", &legacy.plan, &out.plan);
    assert_eq!(legacy.score.makespan.to_bits(), out.score.makespan.to_bits());
    assert_eq!(legacy.score.cost.to_bits(), out.score.cost.to_bits());
}

#[test]
fn heuristic_alias_matches_canonical_name() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    let req = SolveRequest::new(75.0);
    let canon = registry.solve("budget-heuristic", &sys, &req).unwrap();
    let alias = registry.solve("heuristic", &sys, &req).unwrap();
    assert_plans_identical("alias", &canon.plan, &alias.plan);
    assert_eq!(canon.policy, alias.policy);
}

#[test]
fn every_policy_returns_a_valid_partition_and_honest_feasibility() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    let req = SolveRequest::new(80.0)
        .with_deadline(2.0 * 3600.0)
        .with_starts(2)
        .with_sample_frac(0.3);
    for &name in BUILTIN_POLICIES {
        let out = registry.solve(name, &sys, &req).unwrap();
        assert!(
            out.plan.validate_partition(&sys).is_ok(),
            "{name}: plan must partition the workload"
        );
        let rescore = out.plan.score(&sys);
        assert!(
            (rescore.makespan - out.score.makespan).abs() < 1e-6,
            "{name}: reported makespan drifted from the plan"
        );
        if name != "deadline" {
            // Budget policies: the feasible flag is exactly eq. 9.
            assert_eq!(
                out.feasible,
                rescore.satisfies(req.budget),
                "{name}: feasible flag inconsistent"
            );
        } else {
            assert!(
                !out.feasible || out.score.makespan <= 2.0 * 3600.0 + 1e-6,
                "deadline: feasible but misses the deadline"
            );
        }
    }
}
