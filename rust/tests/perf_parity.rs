//! Parity pins for the zero-clone / parallel planning engine: the
//! delta-scored REPLACE and the threaded multistart / sweep paths must be
//! **bit-for-bit identical** to the historical implementations — the
//! optimisation must not move a single float.
//!
//! The clone-per-candidate REPLACE reference below is the pre-optimisation
//! implementation, kept verbatim (over public APIs) as the ground truth.

// Plan clones here ARE the legacy reference path under test.
#![allow(clippy::disallowed_methods)]

use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::model::{Plan, System, TaskId};
use botsched::scheduler::{
    find_multistart, MultiStartConfig, Planner, PlannerConfig, PolicyRegistry, SolveRequest,
};
use botsched::workload::paper::{table1_system, BUDGETS};
use botsched::workload::{SizeDistribution, WorkloadGenerator, WorkloadSpec};

/// Exact structural equality: same VMs in order, same instance types,
/// same task lists.
fn assert_plans_identical(context: &str, a: &Plan, b: &Plan) {
    assert_eq!(a.n_vms(), b.n_vms(), "{context}: VM count differs");
    for (i, (x, y)) in a.vms.iter().zip(&b.vms).enumerate() {
        assert_eq!(x.it, y.it, "{context}: vm{i} instance type differs");
        assert_eq!(x.tasks(), y.tasks(), "{context}: vm{i} task list differs");
    }
}

// ---------------------------------------------------------------------------
// Legacy clone-based REPLACE (pre-optimisation reference).

fn legacy_lpt_spread(sys: &System, plan: &mut Plan, mut tasks: Vec<TaskId>, vms: &[usize]) {
    let it = plan.vms[vms[0]].it;
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    for t in tasks {
        let dst = *vms
            .iter()
            .min_by(|&&a, &&b| plan.vms[a].work().total_cmp(&plan.vms[b].work()))
            .expect("at least one new VM");
        plan.vms[dst].push_task(sys, t);
    }
}

/// The historical REPLACE: materialise every candidate as a full plan
/// clone, batch-score them, commit the winner.
fn legacy_replace(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
) -> bool {
    if plan.is_empty() || k == 0 {
        return false;
    }
    let before = plan.score(sys);
    let remaining = (budget - before.cost).max(0.0);

    let mut candidates: Vec<Plan> = Vec::new();
    let mut present: Vec<bool> = vec![false; sys.n_types()];
    for vm in &plan.vms {
        present[vm.it.index()] = true;
    }
    for (src_idx, src_present) in present.iter().enumerate() {
        if !src_present {
            continue;
        }
        let src_it = sys.instance_types[src_idx].id;
        let src_rate = sys.rate(src_it);
        let mut victims: Vec<usize> = plan
            .vms
            .iter()
            .enumerate()
            .filter(|(_, vm)| vm.it == src_it)
            .map(|(i, _)| i)
            .collect();
        victims.sort_by(|&a, &b| plan.vms[b].exec(sys).total_cmp(&plan.vms[a].exec(sys)));
        victims.truncate(k);
        if victims.is_empty() {
            continue;
        }
        let freed: f64 = victims.iter().map(|&i| plan.vms[i].cost(sys)).sum();

        for cheap in &sys.instance_types {
            if cheap.cost_per_hour >= src_rate {
                continue;
            }
            let n_new = ((freed + remaining) / cheap.cost_per_hour).floor() as usize;
            if n_new == 0 {
                continue;
            }
            let mut cand = plan.clone();
            let mut drained = Vec::new();
            for &v in &victims {
                drained.extend(cand.vms[v].drain_tasks());
            }
            let mut vs = victims.clone();
            vs.sort_unstable_by(|a, b| b.cmp(a));
            for v in vs {
                cand.remove_vm(v);
            }
            let new_ids: Vec<usize> = (0..n_new).map(|_| cand.add_vm(sys, cheap.id)).collect();
            legacy_lpt_spread(sys, &mut cand, drained, &new_ids);
            cand.drop_empty_vms();
            candidates.push(cand);
        }
    }
    if candidates.is_empty() {
        return false;
    }

    let refs: Vec<&Plan> = candidates.iter().collect();
    let scores = evaluator.eval_plans(sys, &refs);

    let mut best: Option<(usize, f64)> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.cost <= budget + 1e-9
            && s.makespan < before.makespan - 1e-9
            && best.as_ref().is_none_or(|(_, m)| s.makespan < *m)
        {
            best = Some((i, s.makespan));
        }
    }
    match best {
        Some((i, _)) => {
            *plan = candidates.swap_remove(i);
            true
        }
        None => false,
    }
}

/// A mid-pipeline plan for REPLACE to act on: Algorithm 1 with the
/// REPLACE phase disabled, so the plan is exactly what FIND would hand
/// REPLACE on its next iteration.
fn pre_replace_plan(sys: &System, budget: f64) -> Plan {
    let cfg = PlannerConfig { enable_replace: false, ..PlannerConfig::default() };
    Planner::new(sys).with_config(cfg).find(budget).plan
}

#[test]
fn delta_replace_bit_identical_on_table1_workload() {
    let sys = table1_system(0.0);
    for &budget in BUDGETS {
        let base = pre_replace_plan(&sys, budget);

        let mut legacy = base.clone();
        let legacy_swapped = legacy_replace(&sys, &mut legacy, budget, 1, &NativeEvaluator);
        let mut delta = base.clone();
        let delta_swapped =
            botsched::scheduler::replace(&sys, &mut delta, budget, 1, &NativeEvaluator);

        assert_eq!(legacy_swapped, delta_swapped, "budget {budget}: commit decision differs");
        assert_plans_identical(&format!("budget {budget}"), &legacy, &delta);
        let (ls, ds) = (legacy.score(&sys), delta.score(&sys));
        assert_eq!(ls.makespan.to_bits(), ds.makespan.to_bits(), "budget {budget}");
        assert_eq!(ls.cost.to_bits(), ds.cost.to_bits(), "budget {budget}");
    }
}

#[test]
fn delta_replace_bit_identical_with_overhead_and_larger_k() {
    // Boot overhead changes which slots bill; k > 1 swaps several VMs.
    let sys = table1_system(30.0);
    for &budget in &[60.0, 80.0, 100.0] {
        for k in [1usize, 2, 3] {
            let base = pre_replace_plan(&sys, budget);
            let mut legacy = base.clone();
            let a = legacy_replace(&sys, &mut legacy, budget, k, &NativeEvaluator);
            let mut delta = base.clone();
            let b = botsched::scheduler::replace(&sys, &mut delta, budget, k, &NativeEvaluator);
            assert_eq!(a, b, "budget {budget}, k {k}");
            assert_plans_identical(&format!("budget {budget}, k {k}"), &legacy, &delta);
        }
    }
}

#[test]
fn delta_replace_bit_identical_on_the_paper_example() {
    // The Sec. IV-G example: one $2 VM must trade for two $1 VMs.
    let sys = botsched::model::SystemBuilder::new()
        .app("a", vec![1.0; 10])
        .instance_type("exp", 2.0, vec![8.0])
        .instance_type("cheap", 1.0, vec![10.0])
        .build()
        .unwrap();
    let mut base = Plan::new();
    let v = base.add_vm(&sys, botsched::model::InstanceTypeId(0));
    for t in 0..10 {
        base.vms[v].push_task(&sys, TaskId(t));
    }

    let mut legacy = base.clone();
    assert!(legacy_replace(&sys, &mut legacy, 2.0, 1, &NativeEvaluator));
    let mut delta = base.clone();
    assert!(botsched::scheduler::replace(&sys, &mut delta, 2.0, 1, &NativeEvaluator));
    assert_plans_identical("paper example", &legacy, &delta);
    assert_eq!(delta.score(&sys).makespan, 50.0);
}

#[test]
fn delta_replace_bit_identical_on_random_instances() {
    let mut generator = WorkloadGenerator::new(2024);
    for seed in 0..6u64 {
        let spec = WorkloadSpec {
            n_apps: 2 + (seed % 3) as usize,
            n_types: 3 + (seed % 4) as usize,
            tasks_per_app: 40,
            sizes: SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
            ..Default::default()
        };
        let sys = generator.system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.3);
        let base = pre_replace_plan(&sys, budget);
        let mut legacy = base.clone();
        let a = legacy_replace(&sys, &mut legacy, budget, 1, &NativeEvaluator);
        let mut delta = base.clone();
        let b = botsched::scheduler::replace(&sys, &mut delta, budget, 1, &NativeEvaluator);
        assert_eq!(a, b, "seed {seed}");
        assert_plans_identical(&format!("seed {seed}"), &legacy, &delta);
    }
}

// ---------------------------------------------------------------------------
// Thread-count parity: multistart and the sweep grid.

#[test]
fn multistart_bit_identical_across_thread_counts() {
    let sys = table1_system(0.0);
    for &budget in &[60.0, 80.0] {
        let baseline = find_multistart(
            &sys,
            budget,
            &MultiStartConfig { n_starts: 4, seed: 9, threads: 1, ..Default::default() },
            &NativeEvaluator,
        );
        for threads in [2usize, 4, 0] {
            let got = find_multistart(
                &sys,
                budget,
                &MultiStartConfig { n_starts: 4, seed: 9, threads, ..Default::default() },
                &NativeEvaluator,
            );
            let ctx = format!("budget {budget}, threads {threads}");
            assert_plans_identical(&ctx, &baseline.plan, &got.plan);
            assert_eq!(baseline.score.makespan.to_bits(), got.score.makespan.to_bits(), "{ctx}");
            assert_eq!(baseline.score.cost.to_bits(), got.score.cost.to_bits(), "{ctx}");
            assert_eq!(baseline.feasible, got.feasible, "{ctx}");
            assert_eq!(baseline.iterations, got.iterations, "{ctx}");
        }
    }
}

#[test]
fn multistart_policy_threads_knob_bit_identical() {
    // The same parity through the Policy API (the knob wire clients use).
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    let base = registry
        .solve("multistart", &sys, &SolveRequest::new(80.0).with_seed(9).with_starts(4))
        .unwrap();
    for threads in [2usize, 4] {
        let req = SolveRequest::new(80.0).with_seed(9).with_starts(4).with_threads(threads);
        let got = registry.solve("multistart", &sys, &req).unwrap();
        assert_plans_identical(&format!("threads {threads}"), &base.plan, &got.plan);
        assert_eq!(base.score.makespan.to_bits(), got.score.makespan.to_bits());
        assert_eq!(base.score.cost.to_bits(), got.score.cost.to_bits());
    }
}

#[test]
fn sweep_bit_identical_across_thread_counts() {
    let sys = table1_system(0.0);
    let budgets = [45.0, 60.0, 80.0];
    let baseline = botsched::analysis::run_sweep(&sys, &budgets, &NativeEvaluator);
    for threads in [2usize, 4, 0] {
        let got = botsched::analysis::run_sweep_threads(&sys, &budgets, &NativeEvaluator, threads);
        assert_eq!(baseline.rows.len(), got.rows.len(), "threads {threads}");
        for (a, b) in baseline.rows.iter().zip(&got.rows) {
            let ctx = format!("threads {threads}, {} @ {}", a.approach, a.budget);
            assert_eq!(a.approach, b.approach, "{ctx}");
            assert_eq!(a.budget, b.budget, "{ctx}");
            assert_eq!(a.score.makespan.to_bits(), b.score.makespan.to_bits(), "{ctx}");
            assert_eq!(a.score.cost.to_bits(), b.score.cost.to_bits(), "{ctx}");
            assert_eq!(a.feasible, b.feasible, "{ctx}");
            assert_eq!(a.vm_mix, b.vm_mix, "{ctx}");
        }
    }
}

#[test]
fn full_planner_scores_stay_consistent_after_the_replace_rewrite() {
    // End-to-end guard on FIND (which calls REPLACE every iteration):
    // the committed plan partitions the workload and its reported score
    // is exactly what the evaluator says about that plan.  (Bit-parity
    // of the REPLACE phase itself is pinned by the tests above.)
    let sys = table1_system(0.0);
    for &budget in &[60.0, 80.0] {
        let report = Planner::new(&sys).find(budget);
        assert!(report.plan.validate_partition(&sys).is_ok(), "budget {budget}");
        // The reported score is the evaluator's verdict on the committed
        // plan: re-scoring through the same path must be bit-stable, and
        // the plan's own per-task arithmetic agrees to float tolerance.
        let re_eval = NativeEvaluator.eval_plan(&sys, &report.plan);
        assert_eq!(re_eval.makespan.to_bits(), report.score.makespan.to_bits());
        assert_eq!(re_eval.cost.to_bits(), report.score.cost.to_bits());
        let direct = report.plan.score(&sys);
        assert!((direct.makespan - report.score.makespan).abs() < 1e-9);
        assert!((direct.cost - report.score.cost).abs() < 1e-9);
    }
}
