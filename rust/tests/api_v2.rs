//! The typed API's own test suite: encode→decode round-trips over every
//! `Request`/`Response` variant (including boundary values), per-op
//! client-vs-raw-JSON parity over a live coordinator (a v2 typed client
//! and a raw v1 line must receive byte-identical success bodies), and
//! the `describe` schema drift snapshot — the test that fails when an
//! op or field changes without the snapshot being updated.

use botsched::coordinator::api::{
    describe_schema, ApiError, CampaignRequest, CampaignResponse, CancelRequest, ChaosAction,
    ChaosRequest, EngineInfo, ErrorCode, EstimatePerfRequest, EstimatePerfResponse, NoiseSpec,
    PersistAction, PersistRequest, Placement, PlanRequest,
    PlanResponse, PlannerOverrides, ReplicationSummary, Request, Response, RunRow, ShardRow,
    SimulateRequest, SimulateResponse, SolveParams, StatsResponse, StatusRequest, SubmitRequest,
    SweepRequest, SweepResponse, SystemRef, SystemSpec, VmRow,
};
use botsched::coordinator::server::request as raw_request;
use botsched::coordinator::{Client, Coordinator, CoordinatorConfig};
use botsched::util::Json;

fn roundtrip(req: Request) {
    let encoded = req.encode();
    let back = Request::decode(&encoded)
        .unwrap_or_else(|e| panic!("decode({encoded}) failed: {e}"));
    assert_eq!(back, req, "round-trip drift through {encoded}");
    // A second encode is bit-stable (canonical form).
    assert_eq!(back.encode().to_string(), encoded.to_string());
}

#[test]
fn every_request_variant_roundtrips() {
    roundtrip(Request::Ping);
    roundtrip(Request::Stats);
    roundtrip(Request::Shutdown);
    roundtrip(Request::Jobs);
    roundtrip(Request::ListPolicies);
    roundtrip(Request::ListScenarios);
    roundtrip(Request::Describe);
    roundtrip(Request::Plan(PlanRequest::new(80.0)));
    roundtrip(Request::Plan(
        PlanRequest::new(80.0)
            .with_policy("multistart")
            .with_deadline(3600.0)
            .with_seed(7)
            .with_threads(4)
            .with_target(SystemRef::scenario("heavy-tail"))
            .with_detail(),
    ));
    roundtrip(Request::Simulate(
        SimulateRequest::new(80.0)
            .with_noise(NoiseSpec {
                task_sigma: Some(0.1),
                boot_sigma: Some(0.05),
                mean_lifetime: Some(2500.0),
            })
            .with_seed(3)
            .with_target(SystemRef::named("paper:30")),
    ));
    roundtrip(Request::Sweep(
        SweepRequest::default().with_budgets(vec![40.0, 60.5, 80.0]).with_threads(2),
    ));
    roundtrip(Request::Campaign(
        CampaignRequest::new(150.0)
            .with_policy("mi")
            .with_noise(NoiseSpec { mean_lifetime: Some(2500.0), ..NoiseSpec::default() })
            .with_seed(3)
            .with_max_rounds(6)
            .with_replications(64)
            .with_threads(8),
    ));
    roundtrip(Request::EstimatePerf(EstimatePerfRequest {
        target: SystemRef::default(),
        per_cell: Some(20),
        noise: Some(NoiseSpec { task_sigma: Some(0.05), ..NoiseSpec::default() }),
        seed: Some(9),
    }));
    roundtrip(Request::Submit(SubmitRequest::from_request(
        &Request::Plan(PlanRequest::new(80.0)),
        Placement { priority: Some(7), deadline_ms: Some(30_000) },
    )));
    roundtrip(Request::Status(StatusRequest {
        job_id: "j-3".into(),
        partials_from: Some(17),
    }));
    roundtrip(Request::Cancel(CancelRequest { job_id: "j-3".into() }));
    roundtrip(Request::Persist(PersistRequest { action: PersistAction::Stats }));
    roundtrip(Request::Persist(PersistRequest { action: PersistAction::Compact }));
    roundtrip(Request::Health);
    roundtrip(Request::Chaos(ChaosRequest { action: ChaosAction::List }));
    roundtrip(Request::Chaos(ChaosRequest {
        action: ChaosAction::Arm("journal.fsync=error@0.5x3".into()),
    }));
    roundtrip(Request::Chaos(ChaosRequest { action: ChaosAction::Disarm(None) }));
    roundtrip(Request::Chaos(ChaosRequest {
        action: ChaosAction::Disarm(Some("journal.fsync".into())),
    }));
}

#[test]
fn boundary_values_roundtrip_and_out_of_range_rejects() {
    // Queue placement extremes on submit and sync sweep/campaign.
    for (priority, deadline_ms) in
        [(Some(0u64), Some(0u64)), (Some(9), Some(86_400_000_000)), (None, None)]
    {
        roundtrip(Request::Submit(SubmitRequest::from_request(
            &Request::Plan(PlanRequest::new(1.0)),
            Placement { priority, deadline_ms },
        )));
        roundtrip(Request::Sweep(SweepRequest {
            budgets: Some(vec![1.0]),
            placement: Placement { priority, deadline_ms },
            ..SweepRequest::default()
        }));
    }
    // Thread-count bounds (0 = auto, 256 = ceiling) and the solver-knob
    // edges; remaining may name task id u32::MAX.
    let mut params = SolveParams::new(0.0);
    params.threads = Some(0);
    params.perf_jitter = Some(0.0);
    params.sample_frac = Some(1.0);
    params.n_starts = Some(1);
    params.remaining = Some(vec![0, u32::MAX]);
    params.planner = Some(PlannerOverrides {
        max_iters: Some(0),
        replace_k: Some(3),
        enable_split: Some(false),
        ..PlannerOverrides::default()
    });
    roundtrip(Request::Plan(PlanRequest {
        params,
        target: SystemRef { overhead: Some(30.0), ..SystemRef::default() },
        detail: false,
    }));
    let mut params = SolveParams::new(1e9);
    params.threads = Some(256);
    roundtrip(Request::Plan(PlanRequest {
        params,
        target: SystemRef {
            system: Some(SystemSpec::Inline(
                Json::parse(r#"{"apps":[{"task_sizes":[1]}]}"#).unwrap(),
            )),
            ..SystemRef::default()
        },
        detail: true,
    }));
    roundtrip(Request::Campaign(CampaignRequest::new(1.0).with_replications(4096)));
    // One-past-the-edge rejects with the bad_request code.
    for bad in [
        r#"{"op":"plan","budget":1,"threads":257}"#,
        r#"{"op":"campaign","budget":1,"replications":4097}"#,
        r#"{"op":"submit","priority":10,"job":{"op":"ping"}}"#,
        r#"{"op":"submit","deadline_ms":86400000001,"job":{"op":"ping"}}"#,
        r#"{"op":"plan","budget":1,"perf_jitter":1.0}"#,
        r#"{"op":"plan","budget":1,"sample_frac":0}"#,
        r#"{"op":"plan","budget":1,"remaining":[]}"#,
        r#"{"op":"plan","budget":1,"remaining":[4294967296]}"#,
    ] {
        let e = Request::decode(&Json::parse(bad).unwrap()).unwrap_err();
        assert_eq!(e.code, ErrorCode::BadRequest, "{bad}");
    }
}

fn resp_roundtrip(resp: &Response, decode: impl Fn(&Json) -> Response) {
    let body = resp.encode();
    assert_eq!(body.get("ok"), Some(&Json::Bool(true)), "{body}");
    let back = decode(&body);
    assert_eq!(&back, resp, "response round-trip drift through {body}");
    assert_eq!(back.encode().to_string(), body.to_string());
}

#[test]
fn every_response_variant_roundtrips() {
    let plan = PlanResponse {
        policy: "budget-heuristic".into(),
        approach: "heuristic".into(),
        budget: 80.0,
        effective_budget: 78.5,
        makespan: 6260.4,
        cost: 78.0,
        feasible: true,
        iterations: 4,
        probes: 1,
        vms: vec![
            VmRow { instance_type: "it2.large".into(), tasks: 120, exec: 3000.5, cost: 12.0 },
            VmRow { instance_type: "it1".into(), tasks: 0, exec: 0.0, cost: 5.0 },
        ],
        plan: Some(Json::parse(r#"{"vms":[]}"#).unwrap()),
    };
    resp_roundtrip(&Response::Plan(Box::new(plan)), |b| {
        Response::Plan(Box::new(PlanResponse::decode(b).unwrap()))
    });
    resp_roundtrip(
        &Response::Simulate(SimulateResponse {
            policy: "mp".into(),
            planned_feasible: false,
            makespan: 100.0,
            cost: 9.0,
            completed: 750,
            stranded: 0,
            failures: 3,
        }),
        |b| Response::Simulate(SimulateResponse::decode(b).unwrap()),
    );
    resp_roundtrip(
        &Response::Sweep(SweepResponse {
            sweep: Json::parse(r#"{"rows":[{"budget":60,"policy":"mi"}]}"#).unwrap(),
        }),
        |b| Response::Sweep(SweepResponse::decode(b).unwrap()),
    );
    resp_roundtrip(
        &Response::Campaign(CampaignResponse::Single {
            policy: "deadline".into(),
            wall_clock: 7205.0,
            spent: 149.0,
            complete: true,
            within_budget: true,
            rounds: 3,
            planned_makespan: 3600.0,
            cancelled: false,
        }),
        |b| Response::Campaign(CampaignResponse::decode(b).unwrap()),
    );
    resp_roundtrip(
        &Response::Campaign(CampaignResponse::Replicated {
            policy: "mi".into(),
            replications: 2,
            cancelled: true,
            summary: Some(ReplicationSummary {
                complete_frac: 0.5,
                within_budget_frac: 1.0,
                mean_wall_clock: 9000.0,
                mean_spent: 140.5,
                runs: vec![
                    RunRow {
                        wall_clock: 8000.0,
                        spent: 141.0,
                        complete: true,
                        within_budget: true,
                        rounds: 2,
                    },
                    RunRow {
                        wall_clock: 10000.0,
                        spent: 140.0,
                        complete: false,
                        within_budget: true,
                        rounds: 4,
                    },
                ],
            }),
        }),
        |b| Response::Campaign(CampaignResponse::decode(b).unwrap()),
    );
    // Cancelled-before-anything-ran: no aggregate block.
    resp_roundtrip(
        &Response::Campaign(CampaignResponse::Replicated {
            policy: "mi".into(),
            replications: 0,
            cancelled: true,
            summary: None,
        }),
        |b| Response::Campaign(CampaignResponse::decode(b).unwrap()),
    );
    resp_roundtrip(
        &Response::EstimatePerf(EstimatePerfResponse {
            samples: 240,
            estimate: vec![20.0, 24.5, 18.0],
            max_rel_error: 1e-9,
        }),
        |b| Response::EstimatePerf(EstimatePerfResponse::decode(b).unwrap()),
    );
    resp_roundtrip(
        &Response::Stats(StatsResponse {
            stats: Json::parse(r#"{"requests":7}"#).unwrap(),
            engine: EngineInfo {
                shards: 2,
                queued: 1,
                max_backlog: 256,
                shard_stats: vec![
                    ShardRow { shard: 0, depth: 1, high_water: 3, rejected: 0 },
                    ShardRow { shard: 1, depth: 0, high_water: 1, rejected: 2 },
                ],
            },
        }),
        |b| Response::Stats(StatsResponse::decode(b).unwrap()),
    );
    let persist = Response::Persist {
        persist: Json::parse(r#"{"cache":{"enabled":false},"journal":{"enabled":false}}"#)
            .unwrap(),
    };
    assert_eq!(
        persist.encode().to_string(),
        r#"{"ok":true,"persist":{"cache":{"enabled":false},"journal":{"enabled":false}}}"#
    );
    // The fixed-shape variants (plus ApiError, pinned in the api unit
    // tests) complete the surface.
    assert_eq!(Response::Pong.encode().to_string(), r#"{"ok":true,"pong":true}"#);
    assert_eq!(Response::Bye.encode().to_string(), r#"{"bye":true,"ok":true}"#);
    assert_eq!(
        Response::Submitted { job_id: "j-9".into() }.encode().to_string(),
        r#"{"job_id":"j-9","ok":true}"#
    );
    assert_eq!(
        Response::Cancelled { cancelled: true }.encode().to_string(),
        r#"{"cancelled":true,"ok":true}"#
    );
    let err = ApiError::bad_request("x");
    assert_eq!(ApiError::decode(&err.encode_v2()), Some(err));
}

// ---------------------------------------------------------------------------
// Client vs raw JSON: per-op byte parity over a live coordinator.

/// Drop measured wall-time fields (sweep rows carry `plan_micros`, the
/// real planning time) — everything else in the replies is
/// deterministic and must match byte-for-byte.
fn strip_timings(j: &Json) -> Json {
    match j {
        Json::Obj(m) => Json::Obj(
            m.iter()
                .filter(|(k, _)| k.as_str() != "plan_micros")
                .map(|(k, v)| (k.clone(), strip_timings(v)))
                .collect(),
        ),
        Json::Arr(a) => Json::Arr(a.iter().map(strip_timings).collect()),
        other => other.clone(),
    }
}

#[test]
fn typed_v2_client_and_raw_v1_lines_get_identical_success_bodies() {
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts");
    let addr = coord.local_addr;
    let mut client = Client::connect(&addr).unwrap();

    // (raw v1 line, typed request) per deterministic op.  The raw lines
    // are the explicit v1-parity fixtures.
    let cases: Vec<(&str, Request)> = vec![
        (r#"{"op":"ping"}"#, Request::Ping),
        (r#"{"op":"list_policies"}"#, Request::ListPolicies),
        (r#"{"op":"list_scenarios"}"#, Request::ListScenarios),
        (r#"{"op":"plan","budget":80}"#, Request::Plan(PlanRequest::new(80.0))),
        (
            r#"{"op":"plan","budget":80,"policy":"mp","detail":true}"#,
            Request::Plan(PlanRequest::new(80.0).with_policy("mp").with_detail()),
        ),
        (
            r#"{"op":"plan","budget":200,"policy":"deadline","deadline":3600,"threads":2}"#,
            Request::Plan(
                PlanRequest::new(200.0)
                    .with_policy("deadline")
                    .with_deadline(3600.0)
                    .with_threads(2),
            ),
        ),
        (
            r#"{"op":"plan","budget":500,"scenario":"heavy-tail"}"#,
            Request::Plan(PlanRequest::new(500.0).with_target(SystemRef::scenario("heavy-tail"))),
        ),
        (
            r#"{"op":"simulate","budget":80,"noise":{"task_sigma":0.05},"seed":3}"#,
            Request::Simulate(
                SimulateRequest::new(80.0)
                    .with_noise(NoiseSpec { task_sigma: Some(0.05), ..NoiseSpec::default() })
                    .with_seed(3),
            ),
        ),
        (
            r#"{"op":"sweep","budgets":[60,80]}"#,
            Request::Sweep(SweepRequest::default().with_budgets(vec![60.0, 80.0])),
        ),
        (
            r#"{"op":"campaign","budget":150,"noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
            Request::Campaign(
                CampaignRequest::new(150.0)
                    .with_noise(NoiseSpec { mean_lifetime: Some(2500.0), ..NoiseSpec::default() })
                    .with_seed(3)
                    .with_max_rounds(6),
            ),
        ),
        (
            r#"{"op":"estimate_perf","per_cell":5}"#,
            Request::EstimatePerf(EstimatePerfRequest {
                per_cell: Some(5),
                ..EstimatePerfRequest::default()
            }),
        ),
    ];
    for (raw_line, typed) in cases {
        let raw = raw_request(&addr, raw_line).expect(raw_line);
        assert_eq!(raw.get("ok"), Some(&Json::Bool(true)), "{raw_line}: {raw}");
        let via_client = client.call(&typed).unwrap_or_else(|e| panic!("{raw_line}: {e}"));
        assert_eq!(
            strip_timings(&raw).to_string(),
            strip_timings(&via_client).to_string(),
            "typed v2 reply differs from raw v1 for {raw_line}"
        );
    }
    client.shutdown().unwrap();
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// Schema drift snapshot.

/// Compact one line per op: `name = field!type, ...` (`!` marks
/// required).  Regenerate by updating `api::OP_SPECS` *and* this table
/// together — that is the point of the test.
const SCHEMA_SNAPSHOT: &[&str] = &[
    "ping =",
    "stats =",
    "health =",
    "list_policies =",
    "list_scenarios =",
    "describe =",
    "persist = action:string",
    "chaos = action:string spec:string point:string",
    "plan = budget!number policy:string approach:string deadline:number seed:integer \
     n_starts:integer perf_jitter:number sample_frac:number threads:integer \
     remaining:array[integer] planner:object system:string|object scenario:string \
     overhead:number detail:bool",
    "simulate = budget!number policy:string approach:string deadline:number seed:integer \
     n_starts:integer perf_jitter:number sample_frac:number threads:integer \
     remaining:array[integer] planner:object system:string|object scenario:string \
     overhead:number noise:object",
    "sweep = budgets:array[number] threads:integer system:string|object scenario:string \
     overhead:number priority:integer deadline_ms:integer",
    "campaign = budget!number policy:string approach:string deadline:number seed:integer \
     n_starts:integer perf_jitter:number sample_frac:number threads:integer planner:object \
     system:string|object scenario:string overhead:number noise:object max_rounds:integer \
     replications:integer priority:integer deadline_ms:integer",
    "estimate_perf = per_cell:integer noise:object seed:integer system:string|object \
     scenario:string overhead:number",
    "submit = job!object priority:integer deadline_ms:integer",
    "status = job_id!string partials_from:integer",
    "jobs =",
    "cancel = job_id!string",
    "shutdown =",
];

#[test]
fn describe_schema_matches_the_snapshot() {
    let schema = describe_schema();
    assert_eq!(schema.get("v").unwrap().as_u64(), Some(2));
    assert_eq!(
        schema.get("versions").unwrap().as_arr().unwrap(),
        &[Json::num(1.0), Json::num(2.0)]
    );
    let codes: Vec<&str> = schema
        .get("error_codes")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_str().unwrap())
        .collect();
    assert_eq!(
        codes,
        [
            "bad_request",
            "unknown_policy",
            "unknown_op",
            "busy",
            "cancelled",
            "evicted",
            "internal",
            "deadline_exceeded",
        ]
    );
    let scenarios: Vec<&str> = schema
        .get("scenarios")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|s| s.as_str().unwrap())
        .collect();
    assert_eq!(scenarios, ["paper", "uniform-small", "heavy-tail", "wide-catalogue"]);
    // Render each op to the snapshot's compact line form.
    let rendered: Vec<String> = schema
        .get("ops")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|op| {
            let fields: Vec<String> = op
                .get("fields")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|f| {
                    format!(
                        "{}{}{}",
                        f.get("name").unwrap().as_str().unwrap(),
                        if f.get("required").unwrap().as_bool().unwrap() { "!" } else { ":" },
                        f.get("type").unwrap().as_str().unwrap(),
                    )
                })
                .collect();
            let mut line = format!("{} =", op.get("op").unwrap().as_str().unwrap());
            if !fields.is_empty() {
                line.push(' ');
                line.push_str(&fields.join(" "));
            }
            line
        })
        .collect();
    let expected: Vec<String> = SCHEMA_SNAPSHOT
        .iter()
        .map(|l| l.split_whitespace().collect::<Vec<_>>().join(" "))
        .collect();
    assert_eq!(
        rendered, expected,
        "describe schema drifted — update api::OP_SPECS and SCHEMA_SNAPSHOT together"
    );
    // Every op also documents a non-empty doc string.
    for op in schema.get("ops").unwrap().as_arr().unwrap() {
        assert!(!op.get("doc").unwrap().as_str().unwrap().is_empty());
    }
}
