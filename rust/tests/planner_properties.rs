//! Property-based tests over randomly generated problem instances.
//!
//! The offline build has no proptest crate, so this is a seeded-sweep
//! mini-framework: each property runs over a few dozen generated systems
//! (deterministic seeds — failures reproduce exactly) and asserts the
//! paper's invariants:
//!
//! * eq. 3/4 — every plan returned by any entry point partitions `T`;
//! * eq. 9  — the `feasible` flag always matches `cost <= B`;
//! * phase monotonicity — REDUCE never raises cost, BALANCE never raises
//!   makespan (within its cap), SPLIT respects budget;
//! * the LP cost floor is never beaten (no plan is cheaper than the
//!   relaxation optimum);
//! * the noiseless simulator agrees with the analytic score.

use botsched::analysis::bounds::{fractional_cost_floor, makespan_floor};
use botsched::cloudsim::{SimConfig, Simulator};
use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::model::BillingPolicy;
use botsched::scheduler::{
    balance, maximise_parallelism, minimise_individual, reduce, split, Planner, ReduceMode,
};
use botsched::workload::{SizeDistribution, WorkloadGenerator, WorkloadSpec};

/// Deterministic family of test systems: varied app/type counts, size
/// distributions, overheads and billing policies.
fn cases(n: usize) -> impl Iterator<Item = (u64, botsched::model::System, f64)> {
    (0..n as u64).map(|seed| {
        let mut gen = WorkloadGenerator::new(seed * 7919 + 13);
        let spec = WorkloadSpec {
            n_apps: 1 + (seed % 4) as usize,
            n_types: 2 + (seed % 5) as usize,
            tasks_per_app: 20 + (seed % 3) as usize * 40,
            sizes: match seed % 3 {
                0 => SizeDistribution::EquallySpaced { lo: 1, hi: 5 },
                1 => SizeDistribution::Uniform { lo: 0.5, hi: 8.0 },
                _ => SizeDistribution::LogNormal { mu: 0.8, sigma: 0.6 },
            },
            overhead: (seed % 4) as f64 * 45.0,
            billing: if seed % 5 == 4 { BillingPolicy::PerSecond } else { BillingPolicy::HourlyCeil },
            ..Default::default()
        };
        let sys = gen.system(&spec);
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.2 + (seed % 3) as f64 * 0.6);
        (seed, sys, budget)
    })
}

#[test]
fn prop_find_returns_valid_partition_and_consistent_feasibility() {
    for (seed, sys, budget) in cases(40) {
        let report = Planner::new(&sys).find(budget);
        assert!(
            report.plan.validate_partition(&sys).is_ok(),
            "seed {seed}: partition violated: {:?}",
            report.plan.validate_partition(&sys)
        );
        let rescore = report.plan.score(&sys);
        assert!(
            (rescore.makespan - report.score.makespan).abs() < 1e-6,
            "seed {seed}: stored makespan drifted"
        );
        assert_eq!(
            report.feasible,
            rescore.satisfies(budget),
            "seed {seed}: feasible flag inconsistent (cost {} budget {budget})",
            rescore.cost
        );
    }
}

#[test]
fn prop_baselines_partition_and_heuristic_competitive() {
    // Per-instance the heuristic may lose to a lucky baseline (it is a
    // heuristic; the paper's claim is about averages), but it must stay
    // within 1.5x on every case and win on average across the family.
    let mut ratios_mi = Vec::new();
    let mut ratios_mp = Vec::new();
    for (seed, sys, budget) in cases(30) {
        let ours = Planner::new(&sys).find(budget);
        for (name, plan) in [
            ("mi", minimise_individual(&sys, budget)),
            ("mp", maximise_parallelism(&sys, budget)),
        ] {
            assert!(plan.validate_partition(&sys).is_ok(), "seed {seed}: {name} partition");
            let base = plan.score(&sys);
            if ours.feasible && base.satisfies(budget) {
                let ratio = ours.score.makespan / base.makespan;
                assert!(
                    ratio <= 1.5,
                    "seed {seed}: heuristic {} vs {name} {} (ratio {ratio:.2})",
                    ours.score.makespan,
                    base.makespan
                );
                if name == "mi" {
                    ratios_mi.push(ratio);
                } else {
                    ratios_mp.push(ratio);
                }
            }
        }
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    assert!(!ratios_mi.is_empty() && !ratios_mp.is_empty());
    assert!(mean(&ratios_mi) <= 1.0 + 1e-9, "loses to MI on average: {}", mean(&ratios_mi));
    assert!(mean(&ratios_mp) <= 1.0 + 1e-9, "loses to MP on average: {}", mean(&ratios_mp));
}

#[test]
fn prop_no_plan_beats_the_lp_cost_floor() {
    for (seed, sys, budget) in cases(30) {
        let floor = fractional_cost_floor(&sys);
        for plan in [
            Planner::new(&sys).find(budget).plan,
            minimise_individual(&sys, budget),
            maximise_parallelism(&sys, budget),
        ] {
            let cost = plan.cost(&sys);
            assert!(
                cost >= floor - 1e-6,
                "seed {seed}: cost {cost} beats LP floor {floor} — impossible"
            );
        }
    }
}

#[test]
fn prop_no_feasible_plan_beats_the_makespan_floor() {
    for (seed, sys, budget) in cases(30) {
        let floor = makespan_floor(&sys, budget);
        let report = Planner::new(&sys).find(budget);
        if report.feasible {
            assert!(
                report.score.makespan >= floor - 1e-6,
                "seed {seed}: makespan {} beats floor {floor} at budget {budget} — bound broken",
                report.score.makespan
            );
        }
    }
}

#[test]
fn prop_reduce_monotone_and_balance_safe() {
    for (seed, sys, budget) in cases(25) {
        let mut plan = botsched::scheduler::initial(&sys, budget);
        let before_cost = plan.cost(&sys);
        reduce(&sys, &mut plan, budget, ReduceMode::Local);
        let mid_cost = plan.cost(&sys);
        assert!(mid_cost <= before_cost + 1e-9, "seed {seed}: local reduce raised cost");
        reduce(&sys, &mut plan, budget, ReduceMode::Global);
        let after_cost = plan.cost(&sys);
        assert!(after_cost <= mid_cost + 1e-9, "seed {seed}: global reduce raised cost");

        let before = plan.score(&sys);
        let cap = before.cost.max(budget);
        balance(&sys, &mut plan, cap);
        let after = plan.score(&sys);
        assert!(after.makespan <= before.makespan + 1e-9, "seed {seed}: balance raised makespan");
        assert!(after.cost <= cap + 1e-9, "seed {seed}: balance broke the cap");

        split(&sys, &mut plan, cap);
        assert!(plan.cost(&sys) <= cap + 1e-9, "seed {seed}: split broke the budget");
        assert!(plan.validate_partition(&sys).is_ok(), "seed {seed}: pipeline partition");
    }
}

#[test]
fn prop_noiseless_sim_matches_analytic_everywhere() {
    for (seed, sys, budget) in cases(25) {
        let report = Planner::new(&sys).find(budget);
        let sim = Simulator::run_plan(&sys, &report.plan, &SimConfig::default());
        assert!(sim.all_done(), "seed {seed}: stranded tasks without failures");
        assert!(
            (sim.makespan - report.score.makespan).abs() < 1e-6,
            "seed {seed}: sim makespan {} vs analytic {}",
            sim.makespan,
            report.score.makespan
        );
        assert!(
            (sim.cost - report.score.cost).abs() < 1e-6,
            "seed {seed}: sim cost {} vs analytic {}",
            sim.cost,
            report.score.cost
        );
    }
}

#[test]
fn prop_native_eval_agrees_with_plan_score() {
    for (seed, sys, budget) in cases(25) {
        let plan = Planner::new(&sys).find(budget).plan;
        let direct = plan.score(&sys);
        let via = NativeEvaluator.eval_plan(&sys, &plan);
        assert!(
            (direct.makespan - via.makespan).abs() < 1e-9
                && (direct.cost - via.cost).abs() < 1e-9,
            "seed {seed}: evaluator disagrees with Plan::score"
        );
    }
}

#[test]
fn prop_more_budget_never_hurts_much() {
    // Monotonicity (soft): doubling the budget should never make the
    // returned makespan materially worse.
    for (seed, sys, budget) in cases(20) {
        let lo = Planner::new(&sys).find(budget);
        let hi = Planner::new(&sys).find(budget * 2.0);
        if lo.feasible && hi.feasible {
            assert!(
                hi.score.makespan <= lo.score.makespan * 1.10 + 1e-6,
                "seed {seed}: budget {budget} -> {}, 2x budget -> {}",
                lo.score.makespan,
                hi.score.makespan
            );
        }
    }
}

// ---------------------------------------------------------------------------
// util::json robustness properties (the wire codec must never panic and
// must round-trip every value it can produce).

use botsched::util::{Json, Rng};

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 1),
        2 => {
            // Finite, JSON-representable numbers only.
            let x = rng.uniform(-1e9, 1e9);
            Json::Num((x * 100.0).round() / 100.0)
        }
        3 => {
            let len = rng.below(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(128) as u8;
                    if c.is_ascii_graphic() || c == b' ' { c as char } else { '\u{00e9}' }
                })
                .collect();
            Json::str(s)
        }
        4 => Json::arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1))),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

#[test]
fn prop_json_roundtrips_random_values() {
    let mut rng = Rng::new(2026);
    for _ in 0..500 {
        let v = random_json(&mut rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("self-produced json failed to parse: {e} in {text}"));
        assert_eq!(back, v, "roundtrip mismatch for {text}");
    }
}

#[test]
fn prop_json_parser_never_panics_on_garbage() {
    let mut rng = Rng::new(7);
    for _ in 0..2000 {
        let len = rng.below(40) as usize;
        let bytes: Vec<u8> = (0..len)
            .map(|_| b"{}[]\",:0123456789.truefalsn \t\n\"e+-"[rng.below(33) as usize])
            .collect();
        if let Ok(text) = std::str::from_utf8(&bytes) {
            let _ = Json::parse(text); // must return, never panic
        }
    }
}

#[test]
fn prop_json_rejects_truncations_of_valid_docs() {
    let mut rng = Rng::new(13);
    for _ in 0..100 {
        let v = random_json(&mut rng, 2);
        let text = v.to_string();
        if text.len() < 2 {
            continue;
        }
        // Any strict prefix either parses to a *different* value (e.g.
        // a shorter number literal) or errors — it must never panic.
        let mut cut = 1 + rng.below((text.len() - 1) as u64) as usize;
        while cut < text.len() && !text.is_char_boundary(cut) {
            cut += 1;
        }
        let _ = Json::parse(&text[..cut.min(text.len())]);
    }
}
