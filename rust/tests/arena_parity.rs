//! Parity pins for the arena/SoA evaluation core: planning and simulation
//! through [`botsched::eval::PlanArena`] must be **bit-for-bit identical**
//! to the historical pointer-chasing `Plan`/`Vm` walk — the cache-layout
//! optimisation must not move a single float.
//!
//! The references below are the pre-arena implementations, kept verbatim
//! (over public APIs) as the ground truth:
//!
//! * `legacy_balance` — BALANCE iterating `plan.vms` directly;
//! * `legacy_replace` — the PR-2 delta-batched REPLACE mutating the plan
//!   in place (descending-index `remove_vm` loop and all);
//! * `legacy_find` — Algorithm 1's loop wiring the two above together and
//!   scoring through `PlanEvaluator::eval_plan`;
//! * `legacy_mi` / `legacy_mp` — the Sec. V baselines over `legacy_balance`;
//! * `legacy_sim_run_plan` — the AoS simulator (per-VM `VecDeque` queues)
//!   before the flattened struct-of-arrays fleet.
//!
//! On top of the pins, property tests check that `Plan -> PlanArena ->
//! Plan` round-trips bit-identically across every `workload::scenario`
//! preset and that arena mutations mirror `Plan` mutations op for op
//! (including free-list slot recycling).

// Plan clones below are the legacy reference implementations and test
// scaffolding — boundary sites for the zero-clone lint.
#![allow(clippy::disallowed_methods)]

use std::collections::VecDeque;

use botsched::cloudsim::{
    run_campaign, run_campaign_replications, CampaignSpec, EventKind, EventQueue, NoiseModel,
    SimConfig, SimOutcome, Simulator, VmStats,
};
use botsched::eval::{DeltaBatch, DeltaCandidate, NativeEvaluator, PlanArena, PlanEvaluator};
use botsched::model::{billed_cost, InstanceTypeId, Plan, PlanScore, System, TaskId};
use botsched::scheduler::{
    add_vms, assign, balance, find_multistart, initial, maximise_parallelism, minimise_individual,
    reduce, replace_cancellable, split, MultiStartConfig, Planner, ReduceMode,
};
use botsched::util::{CancelToken, Rng};
use botsched::workload::paper::BUDGETS;
use botsched::workload::{build_scenario, WorkloadGenerator, SCENARIOS};

// ---------------------------------------------------------------------------
// Assertions.

fn assert_plans_bit_identical(context: &str, a: &Plan, b: &Plan) {
    assert_eq!(a.n_vms(), b.n_vms(), "{context}: VM count differs");
    for (i, (x, y)) in a.vms.iter().zip(&b.vms).enumerate() {
        assert_eq!(x.it, y.it, "{context}: vm{i} instance type differs");
        assert_eq!(x.tasks(), y.tasks(), "{context}: vm{i} task list differs");
        assert_eq!(
            x.work().to_bits(),
            y.work().to_bits(),
            "{context}: vm{i} cached work bits differ"
        );
        assert_eq!(x.agg_sizes().len(), y.agg_sizes().len(), "{context}: vm{i} agg len");
        for (m, (s, t)) in x.agg_sizes().iter().zip(y.agg_sizes()).enumerate() {
            assert_eq!(s.to_bits(), t.to_bits(), "{context}: vm{i} agg[{m}] bits differ");
        }
    }
}

fn assert_scores_bit_identical(context: &str, a: PlanScore, b: PlanScore) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{context}: makespan bits differ");
    assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{context}: cost bits differ");
}

/// Tight / paper-like / loose budgets for any scenario.
fn budgets_for(sys: &System) -> Vec<f64> {
    [0.8, 1.2, 2.0].iter().map(|f| WorkloadGenerator::feasible_budget(sys, *f)).collect()
}

// ---------------------------------------------------------------------------
// Legacy BALANCE (pre-arena reference, verbatim over `plan.vms`).

fn legacy_balance(sys: &System, plan: &mut Plan, cost_cap: f64) -> usize {
    let mut moves = 0usize;
    let budget_moves = plan.n_assigned() * 4 + 16;
    let mut total_cost = plan.cost(sys);
    let mut execs: Vec<f64> = plan.vms.iter().map(|vm| vm.exec(sys)).collect();
    while moves < budget_moves {
        match legacy_best_rebalancing_move(sys, plan, &execs, total_cost, cost_cap) {
            Some((from, to, task, new_cost)) => {
                plan.move_task(sys, from, to, task);
                execs[from] = plan.vms[from].exec(sys);
                execs[to] = plan.vms[to].exec(sys);
                total_cost = new_cost;
                moves += 1;
            }
            None => break,
        }
    }
    moves
}

fn legacy_best_rebalancing_move(
    sys: &System,
    plan: &Plan,
    execs: &[f64],
    total_cost: f64,
    cost_cap: f64,
) -> Option<(usize, usize, TaskId, f64)> {
    if plan.n_vms() < 2 {
        return None;
    }
    let (from, &makespan) = execs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1))?;
    let src = &plan.vms[from];
    if src.is_empty() {
        return None;
    }
    let src_cost = src.cost(sys);

    let mut best: Option<(f64, usize, TaskId, f64)> = None;
    for &task in src.tasks() {
        let t_src = src.task_time(sys, task);
        let src_new_exec = if src.len() == 1 && sys.overhead == 0.0 {
            0.0
        } else {
            sys.overhead + src.work() - t_src
        };
        for (to, dst) in plan.vms.iter().enumerate() {
            if to == from {
                continue;
            }
            let dst_new_exec = sys.overhead + dst.work() + dst.task_time(sys, task);
            let pair_max = src_new_exec.max(dst_new_exec);
            if pair_max >= makespan - 1e-9 {
                continue;
            }
            let src_new_cost = billed_cost(src_new_exec, sys.rate(src.it), sys.hour, sys.billing);
            let dst_new_cost = billed_cost(dst_new_exec, sys.rate(dst.it), sys.hour, sys.billing);
            let new_total =
                total_cost + (src_new_cost - src_cost) + (dst_new_cost - dst.cost(sys));
            if new_total > cost_cap + 1e-9 {
                continue;
            }
            if best.as_ref().is_none_or(|(b, _, _, _)| pair_max < *b) {
                best = Some((pair_max, to, task, new_total));
            }
        }
    }
    best.map(|(_, to, task, new_cost)| (from, to, task, new_cost))
}

// ---------------------------------------------------------------------------
// Legacy delta-batched REPLACE (pre-arena reference, verbatim: borrows
// `Vm::agg_sizes` rows, commits via the descending `remove_vm` loop).

fn legacy_lpt_spread(sys: &System, plan: &mut Plan, mut tasks: Vec<TaskId>, vms: &[usize]) {
    let it = plan.vms[vms[0]].it;
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    for t in tasks {
        let dst = *vms
            .iter()
            .min_by(|&&a, &&b| plan.vms[a].work().total_cmp(&plan.vms[b].work()))
            .expect("at least one new VM");
        plan.vms[dst].push_task(sys, t);
    }
}

fn legacy_lpt_agg_rows(
    sys: &System,
    mut tasks: Vec<TaskId>,
    it: InstanceTypeId,
    n_new: usize,
) -> Vec<Vec<f64>> {
    tasks.sort_by(|&a, &b| sys.exec_time(it, b).total_cmp(&sys.exec_time(it, a)));
    let mut work = vec![0.0f64; n_new];
    let mut agg = vec![vec![0.0f64; sys.n_apps()]; n_new];
    let mut used = vec![false; n_new];
    for t in tasks {
        let dst = (0..n_new).min_by(|&a, &b| work[a].total_cmp(&work[b])).expect("n_new > 0");
        work[dst] += sys.exec_time(it, t);
        let task = sys.task(t);
        agg[dst][task.app.index()] += task.size;
        used[dst] = true;
    }
    agg.into_iter().zip(used).filter_map(|(a, u)| u.then_some(a)).collect()
}

struct LegacySwap {
    victims: Vec<usize>,
    cheap: InstanceTypeId,
    n_new: usize,
}

fn legacy_replace(
    sys: &System,
    plan: &mut Plan,
    budget: f64,
    k: usize,
    evaluator: &dyn PlanEvaluator,
) -> bool {
    if plan.is_empty() || k == 0 {
        return false;
    }
    let before = plan.score(sys);
    let remaining = (budget - before.cost).max(0.0);

    let mut swaps: Vec<LegacySwap> = Vec::new();
    let mut batch = DeltaBatch::new(sys);
    let mut present: Vec<bool> = vec![false; sys.n_types()];
    for vm in &plan.vms {
        present[vm.it.index()] = true;
    }
    for (src_idx, src_present) in present.iter().enumerate() {
        if !src_present {
            continue;
        }
        let src_it = sys.instance_types[src_idx].id;
        let src_rate = sys.rate(src_it);
        let mut victims: Vec<usize> = plan
            .vms
            .iter()
            .enumerate()
            .filter(|(_, vm)| vm.it == src_it)
            .map(|(i, _)| i)
            .collect();
        victims.sort_by(|&a, &b| plan.vms[b].exec(sys).total_cmp(&plan.vms[a].exec(sys)));
        victims.truncate(k);
        if victims.is_empty() {
            continue;
        }
        let freed: f64 = victims.iter().map(|&i| plan.vms[i].cost(sys)).sum();
        let drained: Vec<TaskId> =
            victims.iter().flat_map(|&v| plan.vms[v].tasks().iter().copied()).collect();
        let mut is_victim = vec![false; plan.n_vms()];
        for &v in &victims {
            is_victim[v] = true;
        }

        for cheap in &sys.instance_types {
            if cheap.cost_per_hour >= src_rate {
                continue;
            }
            let n_new = ((freed + remaining) / cheap.cost_per_hour).floor() as usize;
            if n_new == 0 {
                continue;
            }
            let mut cand = DeltaCandidate::default();
            for (i, vm) in plan.vms.iter().enumerate() {
                if is_victim[i] || vm.is_empty() {
                    continue;
                }
                cand.push_vm(sys, vm);
            }
            let perf_new = sys.perf.row(cheap.id);
            for agg in legacy_lpt_agg_rows(sys, drained.clone(), cheap.id, n_new) {
                cand.push_synth(agg, perf_new, cheap.cost_per_hour);
            }
            batch.push(cand);
            swaps.push(LegacySwap { victims: victims.clone(), cheap: cheap.id, n_new });
        }
    }
    if swaps.is_empty() {
        return false;
    }

    let scores = evaluator.eval_deltas(&batch);
    drop(batch);

    let mut best: Option<(usize, f64)> = None;
    for (i, s) in scores.iter().enumerate() {
        if s.cost <= budget + 1e-9
            && s.makespan < before.makespan - 1e-9
            && best.as_ref().is_none_or(|(_, m)| s.makespan < *m)
        {
            best = Some((i, s.makespan));
        }
    }
    let Some((win, _)) = best else {
        return false;
    };

    let LegacySwap { victims, cheap, n_new } = swaps.swap_remove(win);
    let mut drained = Vec::new();
    for &v in &victims {
        drained.extend(plan.vms[v].drain_tasks());
    }
    let mut vs = victims;
    vs.sort_unstable_by(|a, b| b.cmp(a));
    for v in vs {
        plan.remove_vm(v);
    }
    let new_ids: Vec<usize> = (0..n_new).map(|_| plan.add_vm(sys, cheap)).collect();
    legacy_lpt_spread(sys, plan, drained, &new_ids);
    plan.drop_empty_vms();
    true
}

// ---------------------------------------------------------------------------
// Legacy FIND (Algorithm 1 loop, defaults, scoring via eval_plan).

struct LegacyReport {
    plan: Plan,
    score: PlanScore,
    feasible: bool,
    iterations: usize,
}

fn legacy_find(sys: &System, budget: f64, evaluator: &dyn PlanEvaluator) -> LegacyReport {
    let mut plan = initial(sys, budget);
    reduce(sys, &mut plan, budget, ReduceMode::Local);
    plan.drop_empty_vms();

    let mut best = plan.clone();
    let mut best_score = PlanScore { makespan: f64::INFINITY, cost: f64::INFINITY };
    let mut best_feasible = false;

    let mut iterations = 0usize;
    for _ in 0..64 {
        iterations += 1;
        reduce(sys, &mut plan, budget, ReduceMode::Global);
        let cost = plan.cost(sys);
        if cost < budget {
            add_vms(sys, &mut plan, budget - cost);
        }
        let cap = budget.max(plan.cost(sys));
        legacy_balance(sys, &mut plan, cap);
        split(sys, &mut plan, budget);
        let tmp_budget = budget.max(plan.cost(sys));
        legacy_replace(sys, &mut plan, tmp_budget, 1, evaluator);
        plan.drop_empty_vms();

        let score = evaluator.eval_plan(sys, &plan);
        let feasible = score.satisfies(budget);
        let accept = match (feasible, best_feasible) {
            (true, false) => true,
            (false, true) => false,
            _ => score.improves(&best_score),
        };
        if accept {
            best = plan.clone();
            best_score = score;
            best_feasible = feasible;
        } else {
            break;
        }
    }
    LegacyReport { plan: best, score: best_score, feasible: best_feasible, iterations }
}

// ---------------------------------------------------------------------------
// Legacy MI / MP baselines (over legacy_balance).

fn legacy_finish(sys: &System, plan: &mut Plan) {
    if plan.is_empty() {
        plan.add_vm(sys, sys.cheapest_type());
    }
    let tasks: Vec<TaskId> = sys.tasks().iter().map(|t| t.id).collect();
    assign(sys, plan, &tasks);
    legacy_balance(sys, plan, f64::INFINITY);
    plan.drop_empty_vms();
}

fn legacy_mi(sys: &System, budget: f64) -> Plan {
    let mut plan = Plan::new();
    add_vms(sys, &mut plan, budget);
    legacy_finish(sys, &mut plan);
    plan
}

fn legacy_mp(sys: &System, budget: f64) -> Plan {
    let mut plan = Plan::new();
    let it = sys.cheapest_type();
    let n = (budget / sys.rate(it)).floor() as usize;
    for _ in 0..n {
        plan.add_vm(sys, it);
    }
    legacy_finish(sys, &mut plan);
    plan
}

// ---------------------------------------------------------------------------
// Legacy simulator (AoS: per-VM VecDeque queues), pinned-plan path.

struct LegacyVmRuntime {
    it: InstanceTypeId,
    queue: VecDeque<TaskId>,
    in_flight: Option<TaskId>,
    ready_at: f64,
    finished_at: f64,
    busy: f64,
    tasks_done: usize,
    failed: bool,
}

fn legacy_sim_run_plan(sys: &System, plan: &Plan, config: &SimConfig) -> SimOutcome {
    let mut vms: Vec<LegacyVmRuntime> = plan
        .vms
        .iter()
        .map(|vm| LegacyVmRuntime {
            it: vm.it,
            queue: vm.tasks().iter().copied().collect(),
            in_flight: None,
            ready_at: 0.0,
            finished_at: 0.0,
            busy: 0.0,
            tasks_done: 0,
            failed: false,
        })
        .collect();

    let noise = config.noise;
    let mut rng = Rng::new(config.seed);
    let mut q = EventQueue::new();
    let mut completed = Vec::new();
    let mut failures = 0usize;

    fn start_next(
        sys: &System,
        vms: &mut [LegacyVmRuntime],
        vm: usize,
        now: f64,
        noise: &NoiseModel,
        rng: &mut Rng,
        q: &mut EventQueue,
    ) {
        let v = &mut vms[vm];
        if v.failed || v.in_flight.is_some() {
            return;
        }
        let Some(task) = v.queue.pop_front() else {
            return;
        };
        let dur = sys.exec_time(v.it, task) * noise.task_multiplier(rng);
        v.in_flight = Some(task);
        v.busy += dur;
        q.push(now + dur, EventKind::TaskDone { vm, task });
    }

    for (i, vm) in vms.iter_mut().enumerate() {
        let boot = sys.overhead * noise.boot_multiplier(&mut rng);
        vm.ready_at = boot;
        vm.finished_at = boot;
        q.push(boot, EventKind::VmReady { vm: i });
        if let Some(life) = noise.failure_time(&mut rng) {
            q.push(boot + life, EventKind::VmFailed { vm: i });
        }
    }

    while let Some(ev) = q.pop() {
        match ev.kind {
            EventKind::VmReady { vm } => {
                start_next(sys, &mut vms, vm, ev.time, &noise, &mut rng, &mut q);
            }
            EventKind::TaskDone { vm, task } => {
                if vms[vm].failed {
                    continue;
                }
                {
                    let v = &mut vms[vm];
                    v.in_flight = None;
                    v.tasks_done += 1;
                    v.finished_at = ev.time;
                }
                completed.push(task);
                start_next(sys, &mut vms, vm, ev.time, &noise, &mut rng, &mut q);
            }
            EventKind::VmFailed { vm } => {
                let v = &mut vms[vm];
                if v.failed {
                    continue;
                }
                if v.in_flight.is_none() && v.queue.is_empty() {
                    continue;
                }
                v.failed = true;
                v.finished_at = ev.time;
                failures += 1;
            }
        }
    }

    let mut stranded = Vec::new();
    for v in vms.iter() {
        if let Some(t) = v.in_flight {
            stranded.push(t);
        }
        stranded.extend(v.queue.iter().copied());
    }

    let mut cost = 0.0;
    let vm_stats: Vec<VmStats> = vms
        .iter()
        .map(|v| {
            let billed = billed_cost(v.finished_at, sys.rate(v.it), sys.hour, sys.billing);
            cost += billed;
            VmStats {
                it: v.it,
                ready_at: v.ready_at,
                finished_at: v.finished_at,
                busy: v.busy,
                tasks_done: v.tasks_done,
                failed: v.failed,
                billed,
            }
        })
        .collect();
    let makespan = vms.iter().map(|v| v.finished_at).fold(0.0, f64::max);

    SimOutcome { makespan, cost, completed, stranded, vm_stats, failures }
}

// ---------------------------------------------------------------------------
// Plan generators.

/// A deterministic pseudo-random plan: a handful of VMs of mixed types,
/// tasks dealt out with seeded draws (not balanced, not optimised).
fn random_plan(sys: &System, seed: u64) -> Plan {
    let mut rng = Rng::new(seed);
    let n_vms = 2 + (rng.below(6) as usize);
    let mut plan = Plan::new();
    for _ in 0..n_vms {
        let it = InstanceTypeId(rng.below(sys.n_types() as u64) as u32);
        plan.add_vm(sys, it);
    }
    for t in sys.tasks() {
        let v = rng.below(n_vms as u64) as usize;
        plan.vms[v].push_task(sys, t.id);
    }
    plan
}

// ---------------------------------------------------------------------------
// Round-trip property tests.

#[test]
fn plan_arena_round_trips_bit_identically_across_scenarios() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for seed in 0..6u64 {
            let plan = random_plan(&sys, seed);
            let arena = PlanArena::from_plan(&sys, &plan);
            let back = arena.to_plan();
            let ctx = format!("{} seed {seed}", s.name);
            assert_plans_bit_identical(&ctx, &plan, &back);
            assert_scores_bit_identical(&ctx, plan.score(&sys), arena.score(&sys));
            assert!(back.validate_partition(&sys).is_ok(), "{ctx}");
        }
        // Planner outputs round-trip too (post-optimisation shapes).
        for &b in &budgets_for(&sys) {
            let plan = Planner::new(&sys).find(b).plan;
            let arena = PlanArena::from_plan(&sys, &plan);
            let ctx = format!("{} find@{b}", s.name);
            assert_plans_bit_identical(&ctx, &plan, &arena.to_plan());
            assert_scores_bit_identical(&ctx, plan.score(&sys), arena.score(&sys));
        }
    }
}

#[test]
fn arena_mutations_mirror_plan_mutations_including_slot_recycling() {
    let sys = build_scenario("uniform-small").unwrap();
    let mut plan = random_plan(&sys, 42);
    let mut arena = PlanArena::from_plan(&sys, &plan);
    let mut rng = Rng::new(7);

    for step in 0..400 {
        let ctx = format!("step {step}");
        match rng.below(6) {
            // push a task onto a random VM (steal it from its holder).
            0 => {
                if plan.n_vms() >= 2 {
                    let t = TaskId(rng.below(sys.tasks().len() as u64) as u32);
                    let from = plan.vms.iter().position(|vm| vm.tasks().contains(&t));
                    if let Some(from) = from {
                        let to = rng.below(plan.n_vms() as u64) as usize;
                        if to != from {
                            assert_eq!(
                                plan.move_task(&sys, from, to, t),
                                arena.move_task(&sys, from, to, t),
                                "{ctx}: move_task"
                            );
                        }
                    }
                }
            }
            // provision a VM (exercises the free-list on recycled slots).
            1 => {
                let it = InstanceTypeId(rng.below(sys.n_types() as u64) as u32);
                assert_eq!(plan.add_vm(&sys, it), arena.add_vm(it), "{ctx}: add_vm index");
            }
            // drain a random VM.
            2 => {
                if !plan.is_empty() {
                    let v = rng.below(plan.n_vms() as u64) as usize;
                    assert_eq!(
                        plan.vms[v].drain_tasks(),
                        arena.drain_tasks(v),
                        "{ctx}: drain order"
                    );
                }
            }
            // remove a random (drained-or-not) VM.
            3 => {
                if plan.n_vms() >= 2 {
                    let v = rng.below(plan.n_vms() as u64) as usize;
                    plan.vms[v].drain_tasks();
                    arena.drain_tasks(v);
                    plan.remove_vm(v);
                    arena.remove_vm(v);
                }
            }
            // batch removal via the compaction API.
            4 => {
                if plan.n_vms() >= 4 {
                    let a = rng.below(plan.n_vms() as u64) as usize;
                    let b = rng.below(plan.n_vms() as u64) as usize;
                    let mut victims = vec![a, b];
                    victims.sort_unstable();
                    victims.dedup();
                    for &v in &victims {
                        plan.vms[v].drain_tasks();
                        arena.drain_tasks(v);
                    }
                    plan.remove_vms(&victims);
                    arena.remove_vms(&victims);
                }
            }
            // drop empties.
            _ => {
                plan.drop_empty_vms();
                arena.drop_empty_vms();
            }
        }
        assert_plans_bit_identical(&ctx, &plan, &arena.to_plan());
        assert_scores_bit_identical(&ctx, plan.score(&sys), arena.score(&sys));
    }
}

#[test]
fn plan_remove_vms_matches_descending_remove_vm_loop() {
    let sys = build_scenario("heavy-tail").unwrap();
    for seed in 0..8u64 {
        let base = random_plan(&sys, seed);
        let mut rng = Rng::new(seed ^ 0x5eed);
        let mut victims: Vec<usize> = (0..base.n_vms()).filter(|_| rng.below(3) == 0).collect();
        if victims.len() == base.n_vms() {
            victims.pop();
        }
        let mut batch = base.clone();
        let removed = batch.remove_vms(&victims);
        assert_eq!(removed.len(), victims.len(), "seed {seed}");

        let mut loopy = base.clone();
        let mut vs = victims.clone();
        vs.sort_unstable_by(|a, b| b.cmp(a));
        for v in vs {
            loopy.remove_vm(v);
        }
        assert_plans_bit_identical(&format!("seed {seed}"), &batch, &loopy);
    }
}

// ---------------------------------------------------------------------------
// Scoring-path parity: the delta entry point vs the owned batch.

#[test]
fn arena_delta_scoring_matches_eval_plan_bit_for_bit() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for seed in 0..4u64 {
            let plan = random_plan(&sys, seed);
            let ctx = format!("{} seed {seed}", s.name);
            let legacy = NativeEvaluator.eval_plan(&sys, &plan);
            let via_plan = NativeEvaluator.eval_deltas(&DeltaBatch::from_plan(&sys, &plan))[0];
            assert_scores_bit_identical(&ctx, legacy, via_plan);
            let arena = PlanArena::from_plan(&sys, &plan);
            let via_arena = NativeEvaluator.eval_deltas(&arena.delta_batch(&sys))[0];
            assert_scores_bit_identical(&ctx, legacy, via_arena);
        }
    }
}

// ---------------------------------------------------------------------------
// Phase parity: BALANCE and REPLACE.

#[test]
fn balance_matches_legacy_bit_for_bit() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for seed in 0..5u64 {
            for cap_factor in [1.0, 1.5, f64::INFINITY] {
                let base = random_plan(&sys, seed);
                let cap = if cap_factor.is_finite() {
                    base.cost(&sys) * cap_factor
                } else {
                    f64::INFINITY
                };
                let mut legacy = base.clone();
                let legacy_moves = legacy_balance(&sys, &mut legacy, cap);
                let mut arena = base.clone();
                let arena_moves = balance(&sys, &mut arena, cap);
                let ctx = format!("{} seed {seed} cap {cap_factor}", s.name);
                assert_eq!(legacy_moves, arena_moves, "{ctx}: move count");
                assert_plans_bit_identical(&ctx, &legacy, &arena);
            }
        }
    }
}

#[test]
fn replace_matches_legacy_bit_for_bit() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for &b in &budgets_for(&sys) {
            for k in [1usize, 2] {
                let base = {
                    let mut p = initial(&sys, b);
                    reduce(&sys, &mut p, b, ReduceMode::Local);
                    p.drop_empty_vms();
                    p
                };
                let mut legacy = base.clone();
                let l = legacy_replace(&sys, &mut legacy, b, k, &NativeEvaluator);
                let mut arena = base.clone();
                let a = replace_cancellable(
                    &sys,
                    &mut arena,
                    b,
                    k,
                    &NativeEvaluator,
                    &CancelToken::default(),
                );
                let ctx = format!("{} budget {b} k {k}", s.name);
                assert_eq!(l, a, "{ctx}: commit decision");
                assert_plans_bit_identical(&ctx, &legacy, &arena);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// End-to-end policy parity: budget-heuristic, MI, MP, multistart.

#[test]
fn find_matches_legacy_across_scenarios() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for &b in &budgets_for(&sys) {
            let legacy = legacy_find(&sys, b, &NativeEvaluator);
            let report = Planner::new(&sys).find(b);
            let ctx = format!("{} budget {b}", s.name);
            assert_eq!(legacy.iterations, report.iterations, "{ctx}: iteration count");
            assert_eq!(legacy.feasible, report.feasible, "{ctx}: feasibility");
            assert_scores_bit_identical(&ctx, legacy.score, report.score);
            assert_plans_bit_identical(&ctx, &legacy.plan, &report.plan);
        }
    }
}

#[test]
fn find_matches_legacy_on_paper_budget_sweep() {
    let sys = build_scenario("paper").unwrap();
    for &b in BUDGETS {
        let legacy = legacy_find(&sys, b, &NativeEvaluator);
        let report = Planner::new(&sys).find(b);
        let ctx = format!("paper budget {b}");
        assert_eq!(legacy.iterations, report.iterations, "{ctx}");
        assert_scores_bit_identical(&ctx, legacy.score, report.score);
        assert_plans_bit_identical(&ctx, &legacy.plan, &report.plan);
    }
}

#[test]
fn baselines_match_legacy_bit_for_bit() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for &b in &budgets_for(&sys) {
            let ctx = format!("{} budget {b}", s.name);
            let mi = minimise_individual(&sys, b);
            assert_plans_bit_identical(&format!("{ctx} MI"), &legacy_mi(&sys, b), &mi);
            let mp = maximise_parallelism(&sys, b);
            assert_plans_bit_identical(&format!("{ctx} MP"), &legacy_mp(&sys, b), &mp);
        }
    }
}

#[test]
fn multistart_bit_identical_at_thread_counts() {
    for name in ["paper", "heavy-tail"] {
        let sys = build_scenario(name).unwrap();
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.3);
        let base = MultiStartConfig { n_starts: 5, seed: 17, ..Default::default() };
        let one = find_multistart(
            &sys,
            budget,
            &MultiStartConfig { threads: 1, ..base.clone() },
            &NativeEvaluator,
        );
        let four = find_multistart(
            &sys,
            budget,
            &MultiStartConfig { threads: 4, ..base.clone() },
            &NativeEvaluator,
        );
        let ctx = format!("{name} budget {budget}");
        assert_eq!(one.iterations, four.iterations, "{ctx}");
        assert_eq!(one.feasible, four.feasible, "{ctx}");
        assert_scores_bit_identical(&ctx, one.score, four.score);
        assert_plans_bit_identical(&ctx, &one.plan, &four.plan);
    }
}

// ---------------------------------------------------------------------------
// Simulator + campaign parity.

#[test]
fn soa_simulator_matches_legacy_sim_bit_for_bit() {
    let noises = [
        NoiseModel::none(),
        NoiseModel::jitter(0.15),
        NoiseModel::with_failures(0.1, 900.0),
    ];
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        let mut plans: Vec<Plan> = (0..3).map(|seed| random_plan(&sys, seed)).collect();
        let b = WorkloadGenerator::feasible_budget(&sys, 1.2);
        plans.push(Planner::new(&sys).find(b).plan);
        for (pi, plan) in plans.iter().enumerate() {
            for (ni, noise) in noises.iter().enumerate() {
                let cfg = SimConfig { noise: *noise, seed: 31 + ni as u64 };
                let legacy = legacy_sim_run_plan(&sys, plan, &cfg);
                let soa = Simulator::run_plan(&sys, plan, &cfg);
                let ctx = format!("{} plan {pi} noise {ni}", s.name);
                assert_eq!(legacy.makespan.to_bits(), soa.makespan.to_bits(), "{ctx}: makespan");
                assert_eq!(legacy.cost.to_bits(), soa.cost.to_bits(), "{ctx}: cost");
                assert_eq!(legacy.completed, soa.completed, "{ctx}: completion order");
                assert_eq!(legacy.stranded, soa.stranded, "{ctx}: stranded order");
                assert_eq!(legacy.failures, soa.failures, "{ctx}: failures");
                assert_eq!(legacy.vm_stats.len(), soa.vm_stats.len(), "{ctx}");
                for (i, (l, n)) in legacy.vm_stats.iter().zip(&soa.vm_stats).enumerate() {
                    assert_eq!(l.it, n.it, "{ctx} vm{i}");
                    assert_eq!(l.ready_at.to_bits(), n.ready_at.to_bits(), "{ctx} vm{i} ready");
                    assert_eq!(
                        l.finished_at.to_bits(),
                        n.finished_at.to_bits(),
                        "{ctx} vm{i} finished"
                    );
                    assert_eq!(l.busy.to_bits(), n.busy.to_bits(), "{ctx} vm{i} busy");
                    assert_eq!(l.tasks_done, n.tasks_done, "{ctx} vm{i} tasks_done");
                    assert_eq!(l.failed, n.failed, "{ctx} vm{i} failed");
                    assert_eq!(l.billed.to_bits(), n.billed.to_bits(), "{ctx} vm{i} billed");
                }
            }
        }
    }
}

#[test]
fn campaign_replications_bit_identical_at_thread_counts() {
    // A failure-prone campaign exercises the full replanning loop
    // (arena-backed FIND each round) on top of the SoA simulator.
    let sys = build_scenario("paper").unwrap();
    let mut spec = CampaignSpec::new(80.0);
    spec.sim = SimConfig { noise: NoiseModel::with_failures(0.05, 1500.0), seed: 5 };
    let single = run_campaign(&sys, &spec);
    assert!(!single.rounds.is_empty());

    let seq = run_campaign_replications(&sys, &spec, 4, 1);
    let par = run_campaign_replications(&sys, &spec, 4, 4);
    assert_eq!(seq.len(), par.len());
    for (r, (a, b)) in seq.iter().zip(&par).enumerate() {
        let ctx = format!("replication {r}");
        assert_eq!(a.wall_clock.to_bits(), b.wall_clock.to_bits(), "{ctx}: wall clock");
        assert_eq!(a.spent.to_bits(), b.spent.to_bits(), "{ctx}: spend");
        assert_eq!(a.complete, b.complete, "{ctx}");
        assert_eq!(a.rounds.len(), b.rounds.len(), "{ctx}");
        for (i, (x, y)) in a.rounds.iter().zip(&b.rounds).enumerate() {
            assert_eq!(x.makespan.to_bits(), y.makespan.to_bits(), "{ctx} round {i}");
            assert_eq!(x.completed, y.completed, "{ctx} round {i}");
            assert_eq!(x.stranded, y.stranded, "{ctx} round {i}");
        }
    }
}
