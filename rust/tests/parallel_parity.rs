//! Parity pins for deterministic intra-solve parallelism: REPLACE,
//! BALANCE, FIND and multistart must produce **bit-for-bit identical**
//! plans at any thread count (`1`/`2`/`4`/auto) and with REPLACE's
//! bound-based candidate pruning on or off — threading and pruning are
//! pure throughput knobs, never behaviour knobs.
//!
//! Also pinned here:
//!
//! * the [`ReplaceProbe`] accounting contract — with pruning on, REPLACE
//!   performs *no* LPT synthesis for dominated candidates
//!   (`synth == enumerated - pruned`); with pruning off it synthesises
//!   every enumerated pair;
//! * cooperative cancellation — a token fired mid-chunk stops the
//!   parallel scorer without deadlock and discards all partial work, and
//!   a cancelled REPLACE round leaves the arena untouched.

// Plan copies below are test scaffolding — boundary sites for the
// zero-clone lint.
#![allow(clippy::disallowed_methods)]

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};

use botsched::eval::{
    eval_deltas_chunked, DeltaBatch, DeltaCandidate, EvalBatch, NativeEvaluator, PlanArena,
    PlanEvaluator,
};
use botsched::model::{InstanceTypeId, Plan, PlanScore, System, SystemBuilder, TaskId};
use botsched::scheduler::{
    balance_arena, balance_arena_threaded, find_multistart, initial, reduce, replace_arena,
    replace_arena_opts, MultiStartConfig, Planner, ReduceMode, ReplaceOpts, ReplaceProbe,
};
use botsched::util::CancelToken;
use botsched::workload::{build_scenario, WorkloadGenerator, SCENARIOS};

// ---------------------------------------------------------------------------
// Assertions (same contract as the `arena_parity` suite).

fn assert_plans_bit_identical(context: &str, a: &Plan, b: &Plan) {
    assert_eq!(a.n_vms(), b.n_vms(), "{context}: VM count differs");
    for (i, (x, y)) in a.vms.iter().zip(&b.vms).enumerate() {
        assert_eq!(x.it, y.it, "{context}: vm{i} instance type differs");
        assert_eq!(x.tasks(), y.tasks(), "{context}: vm{i} task list differs");
        assert_eq!(
            x.work().to_bits(),
            y.work().to_bits(),
            "{context}: vm{i} cached work bits differ"
        );
        for (m, (s, t)) in x.agg_sizes().iter().zip(y.agg_sizes()).enumerate() {
            assert_eq!(s.to_bits(), t.to_bits(), "{context}: vm{i} agg[{m}] bits differ");
        }
    }
}

fn assert_scores_bit_identical(context: &str, a: PlanScore, b: PlanScore) {
    assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{context}: makespan bits differ");
    assert_eq!(a.cost.to_bits(), b.cost.to_bits(), "{context}: cost bits differ");
}

/// Tight / paper-like / loose budgets for any scenario.
fn budgets_for(sys: &System) -> Vec<f64> {
    [0.8, 1.2, 2.0].iter().map(|f| WorkloadGenerator::feasible_budget(sys, *f)).collect()
}

/// The plan REPLACE rounds start from in these pins: INITIAL + local
/// REDUCE, the same pre-REPLACE state the `arena_parity` suite uses.
fn replace_base(sys: &System, budget: f64) -> Plan {
    let mut p = initial(sys, budget);
    reduce(sys, &mut p, budget, ReduceMode::Local);
    p.drop_empty_vms();
    p
}

// ---------------------------------------------------------------------------
// REPLACE: threads x pruning grid.

#[test]
fn replace_bit_identical_across_threads_and_pruning() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for &b in &budgets_for(&sys) {
            for k in [1usize, 2] {
                let base = replace_base(&sys, b);
                let (ref_swapped, ref_plan) = {
                    let mut arena = PlanArena::from_plan(&sys, &base);
                    let swapped = replace_arena(
                        &sys,
                        &mut arena,
                        b,
                        k,
                        &NativeEvaluator,
                        &CancelToken::default(),
                    );
                    (swapped, arena.to_plan())
                };
                for threads in [1usize, 2, 4] {
                    for prune in [true, false] {
                        let ctx = format!(
                            "{} budget {b} k {k} threads {threads} prune {prune}",
                            s.name
                        );
                        let mut arena = PlanArena::from_plan(&sys, &base);
                        let swapped = replace_arena_opts(
                            &sys,
                            &mut arena,
                            b,
                            k,
                            &NativeEvaluator,
                            &CancelToken::default(),
                            &ReplaceOpts { threads, prune, probe: None },
                        );
                        assert_eq!(swapped, ref_swapped, "{ctx}: commit decision differs");
                        assert_plans_bit_identical(&ctx, &ref_plan, &arena.to_plan());
                    }
                }
            }
        }
    }
}

#[test]
fn replace_probe_accounting_holds_across_scenarios() {
    // With pruning on, no LPT synthesis happens for dominated pairs
    // (synth == enumerated - pruned); with pruning off, every enumerated
    // pair is synthesised and nothing is pruned.  Enumeration itself is
    // independent of the pruning flag.
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        let b = WorkloadGenerator::feasible_budget(&sys, 1.2);
        let base = replace_base(&sys, b);
        let ctx = format!("{} budget {b}", s.name);

        let probe_on = ReplaceProbe::default();
        let mut arena = PlanArena::from_plan(&sys, &base);
        replace_arena_opts(
            &sys,
            &mut arena,
            b,
            1,
            &NativeEvaluator,
            &CancelToken::default(),
            &ReplaceOpts { threads: 2, prune: true, probe: Some(&probe_on) },
        );
        let (enum_on, pruned_on, synth_on) = probe_on.snapshot();
        assert_eq!(synth_on, enum_on - pruned_on, "{ctx}: pruned pairs must not synthesise");

        let probe_off = ReplaceProbe::default();
        let mut arena = PlanArena::from_plan(&sys, &base);
        replace_arena_opts(
            &sys,
            &mut arena,
            b,
            1,
            &NativeEvaluator,
            &CancelToken::default(),
            &ReplaceOpts { threads: 2, prune: false, probe: Some(&probe_off) },
        );
        let (enum_off, pruned_off, synth_off) = probe_off.snapshot();
        assert_eq!(enum_on, enum_off, "{ctx}: enumeration must not depend on pruning");
        assert_eq!(pruned_off, 0, "{ctx}: pruning off must prune nothing");
        assert_eq!(synth_off, enum_off, "{ctx}: pruning off synthesises every pair");
    }
}

#[test]
fn pruning_skips_dominated_candidates_and_preserves_the_winner() {
    // The paper's Sec. IV-G example plus a decoy type that is cheap but
    // hopeless: its spread floor (10 tasks x 1000 s over 4 VMs = 2500 s)
    // can never beat the incumbent 80 s, so pruning must drop exactly
    // that pair — and only it — before any LPT synthesis.
    let sys = SystemBuilder::new()
        .app("a", vec![1.0; 10])
        .instance_type("exp", 2.0, vec![8.0])
        .instance_type("cheap", 1.0, vec![10.0])
        .instance_type("slowcheap", 0.5, vec![1000.0])
        .build()
        .unwrap();
    let mut plan = Plan::new();
    let v = plan.add_vm(&sys, InstanceTypeId(0));
    for t in 0..10 {
        plan.vms[v].push_task(&sys, TaskId(t));
    }
    assert_eq!(plan.score(&sys).makespan, 80.0);

    let run = |prune: bool, probe: &ReplaceProbe| -> (bool, Plan) {
        let mut arena = PlanArena::from_plan(&sys, &plan);
        let swapped = replace_arena_opts(
            &sys,
            &mut arena,
            2.0,
            1,
            &NativeEvaluator,
            &CancelToken::default(),
            &ReplaceOpts { threads: 1, prune, probe: Some(probe) },
        );
        (swapped, arena.to_plan())
    };

    let probe_on = ReplaceProbe::default();
    let (swapped_on, plan_on) = run(true, &probe_on);
    assert!(swapped_on);
    assert_eq!(probe_on.snapshot(), (2, 1, 1), "exp->cheap kept, exp->slowcheap pruned");

    let probe_off = ReplaceProbe::default();
    let (swapped_off, plan_off) = run(false, &probe_off);
    assert!(swapped_off);
    assert_eq!(probe_off.snapshot(), (2, 0, 2));

    assert_plans_bit_identical("pruned vs unpruned winner", &plan_off, &plan_on);
    assert_eq!(plan_on.score(&sys).makespan, 50.0, "the Sec. IV-G swap must still win");
}

// ---------------------------------------------------------------------------
// BALANCE: chunked move search.

#[test]
fn balance_bit_identical_across_threads() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        // Worst-case imbalance: every task on one VM, several receivers
        // of mixed types — long move-search scans each iteration.
        let mut plan = Plan::new();
        let v0 = plan.add_vm(&sys, InstanceTypeId(0));
        for ti in 0..sys.n_types().min(4) {
            plan.add_vm(&sys, InstanceTypeId(ti as u16));
        }
        for t in sys.tasks() {
            plan.vms[v0].push_task(&sys, t.id);
        }
        for cap in [plan.cost(&sys) * 1.5, f64::INFINITY] {
            let mut seq = PlanArena::from_plan(&sys, &plan);
            let seq_moves = balance_arena(&sys, &mut seq, cap);
            let seq_plan = seq.to_plan();
            for threads in [2usize, 4, 0] {
                let ctx = format!("{} cap {cap} threads {threads}", s.name);
                let mut par = PlanArena::from_plan(&sys, &plan);
                let par_moves = balance_arena_threaded(&sys, &mut par, cap, threads);
                assert_eq!(seq_moves, par_moves, "{ctx}: move count differs");
                assert_plans_bit_identical(&ctx, &seq_plan, &par.to_plan());
            }
        }
    }
}

// ---------------------------------------------------------------------------
// FIND and multistart: end-to-end.

#[test]
fn find_bit_identical_across_threads() {
    for s in SCENARIOS {
        let sys = build_scenario(s.name).unwrap();
        for &b in &budgets_for(&sys) {
            let reference = Planner::new(&sys).find(b);
            for threads in [2usize, 4] {
                let ctx = format!("{} budget {b} threads {threads}", s.name);
                let got = Planner::new(&sys).with_threads(threads).find(b);
                assert_eq!(reference.iterations, got.iterations, "{ctx}: iteration count");
                assert_eq!(reference.feasible, got.feasible, "{ctx}: feasibility");
                assert_scores_bit_identical(&ctx, reference.score, got.score);
                assert_plans_bit_identical(&ctx, &reference.plan, &got.plan);
            }
        }
    }
}

#[test]
fn multistart_bit_identical_across_threads_with_nested_discipline() {
    // Multi-start now passes its thread budget *into* FIND when the
    // restart loop is sequential and forces inner threads to 1 when it
    // is parallel — either way the outcome must not move a bit.
    for name in ["paper", "uniform-small"] {
        let sys = build_scenario(name).unwrap();
        let budget = WorkloadGenerator::feasible_budget(&sys, 1.3);
        let base = MultiStartConfig { n_starts: 4, seed: 11, ..Default::default() };
        let one = find_multistart(
            &sys,
            budget,
            &MultiStartConfig { threads: 1, ..base.clone() },
            &NativeEvaluator,
        );
        for threads in [2usize, 4] {
            let ctx = format!("{name} threads {threads}");
            let got = find_multistart(
                &sys,
                budget,
                &MultiStartConfig { threads, ..base.clone() },
                &NativeEvaluator,
            );
            assert_eq!(one.feasible, got.feasible, "{ctx}");
            assert_eq!(one.iterations, got.iterations, "{ctx}");
            assert_scores_bit_identical(&ctx, one.score, got.score);
            assert_plans_bit_identical(&ctx, &one.plan, &got.plan);
        }
        // Single start + many threads: the fan-out is sequential, so the
        // whole thread budget flows into FIND — still bit-identical.
        let single_cfg = MultiStartConfig { n_starts: 1, ..base.clone() };
        let single_seq = find_multistart(&sys, budget, &single_cfg, &NativeEvaluator);
        let single_par = find_multistart(
            &sys,
            budget,
            &MultiStartConfig { threads: 4, ..single_cfg },
            &NativeEvaluator,
        );
        let ctx = format!("{name} single-start");
        assert_scores_bit_identical(&ctx, single_seq.score, single_par.score);
        assert_plans_bit_identical(&ctx, &single_seq.plan, &single_par.plan);
    }
}

// ---------------------------------------------------------------------------
// Cancellation.

/// Scores correctly but fires the cancellation token on every range call
/// — models a caller cancelling while the chunked scorer is mid-flight.
struct CancelMidway {
    token: CancelToken,
    range_calls: AtomicUsize,
}

impl PlanEvaluator for CancelMidway {
    fn eval_batch(&self, batch: &EvalBatch) -> Vec<PlanScore> {
        NativeEvaluator.eval_batch(batch)
    }

    fn supports_chunked_deltas(&self) -> bool {
        true
    }

    fn eval_delta_range(&self, batch: &DeltaBatch<'_>, range: Range<usize>) -> Vec<PlanScore> {
        self.range_calls.fetch_add(1, Ordering::SeqCst);
        self.token.cancel();
        NativeEvaluator.eval_delta_range(batch, range)
    }

    fn name(&self) -> &'static str {
        "cancel-midway"
    }
}

#[test]
fn cancellation_mid_chunk_stops_parallel_scoring_without_deadlock() {
    let sys = build_scenario("uniform-small").unwrap();
    let it = InstanceTypeId(0);
    let mut batch = DeltaBatch::new(&sys);
    for k in 0..128usize {
        let mut c = DeltaCandidate::default();
        c.push_synth(
            (0..sys.n_apps()).map(|m| 1.0 + (k * (m + 1)) as f64 * 0.5).collect(),
            sys.perf.row(it),
            sys.rate(it),
        );
        batch.push(c);
    }
    let token = CancelToken::new();
    let eval = CancelMidway { token: token.clone(), range_calls: AtomicUsize::new(0) };

    // Completing at all proves the pool drained (no deadlock); `None`
    // proves the partial scores were discarded.
    let got = eval_deltas_chunked(&eval, &batch, 4, &token);
    assert!(got.is_none(), "a cancelled chunked scoring must return None");
    let calls = eval.range_calls.load(Ordering::SeqCst);
    assert!(calls >= 1, "at least one chunk must have started");
    // Each worker's first range call fires the token, so no worker ever
    // passes its *second* pre-chunk cancellation poll: with 4 workers
    // over 16 chunks most of the batch must have been skipped.
    assert!(calls <= 4, "cancellation must stop remaining chunks, saw {calls} range calls");
}

#[test]
fn cancelled_replace_round_leaves_the_arena_untouched() {
    let sys = SystemBuilder::new()
        .app("a", vec![1.0; 10])
        .instance_type("exp", 2.0, vec![8.0])
        .instance_type("cheap", 1.0, vec![10.0])
        .build()
        .unwrap();
    let mut plan = Plan::new();
    let v = plan.add_vm(&sys, InstanceTypeId(0));
    for t in 0..10 {
        plan.vms[v].push_task(&sys, TaskId(t));
    }
    let token = CancelToken::new();
    token.cancel();
    for threads in [1usize, 2, 4] {
        let mut arena = PlanArena::from_plan(&sys, &plan);
        let swapped = replace_arena_opts(
            &sys,
            &mut arena,
            2.0,
            1,
            &NativeEvaluator,
            &token,
            &ReplaceOpts { threads, ..Default::default() },
        );
        assert!(!swapped, "threads {threads}: cancelled round must not commit");
        assert_plans_bit_identical(
            &format!("cancelled replace threads {threads}"),
            &plan,
            &arena.to_plan(),
        );
    }
}
