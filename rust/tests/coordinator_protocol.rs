//! End-to-end coordinator tests over real sockets: start the server on an
//! ephemeral port, drive the JSON-line protocol, verify responses and
//! metrics, and exercise concurrent clients against the batching
//! evaluator.

use std::time::Duration;

use botsched::coordinator::server::request;
use botsched::coordinator::{Coordinator, CoordinatorConfig};
use botsched::util::Json;

fn start(batching: bool) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: true, // falls back to native when artifacts absent
        batching,
        batch_wait: Duration::from_millis(1),
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts")
}

#[test]
fn ping_plan_stats_roundtrip() {
    let c = start(true);
    let addr = c.local_addr;

    let r = request(&addr, r#"{"op":"ping"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));

    let r = request(&addr, r#"{"op":"plan","budget":80}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    let makespan = r.get("makespan").unwrap().as_f64().unwrap();
    assert!(makespan > 0.0 && makespan < 10.0 * 3600.0);
    assert_eq!(r.get("feasible"), Some(&Json::Bool(true)));

    let r = request(&addr, r#"{"op":"stats"}"#).unwrap();
    let reqs = r.path(&["stats", "requests"]).unwrap().as_f64().unwrap();
    assert!(reqs >= 2.0);

    c.shutdown();
}

#[test]
fn malformed_requests_keep_connection_alive() {
    let c = start(false);
    let addr = c.local_addr;

    let r = request(&addr, "this is not json").unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get("error").unwrap().as_str().unwrap().contains("bad json"));

    let r = request(&addr, r#"{"op":"unknown_op"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));

    // Server is still healthy.
    let r = request(&addr, r#"{"op":"ping"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));

    c.shutdown();
}

#[test]
fn concurrent_clients_all_served() {
    let c = start(true);
    let addr = c.local_addr;

    let mut handles = Vec::new();
    for i in 0..6u64 {
        handles.push(std::thread::spawn(move || {
            let budget = 60.0 + (i as f64) * 5.0;
            let line = format!(r#"{{"op":"plan","budget":{budget}}}"#);
            request(&addr, &line).unwrap()
        }));
    }
    let responses: Vec<Json> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "response {r}");
        assert!(r.get("makespan").unwrap().as_f64().unwrap() > 0.0);
    }

    c.shutdown();
}

#[test]
fn simulate_campaign_estimate_over_socket() {
    let c = start(false);
    let addr = c.local_addr;

    let r = request(
        &addr,
        r#"{"op":"simulate","budget":80,"noise":{"task_sigma":0.1},"seed":5}"#,
    )
    .unwrap();
    assert_eq!(r.get("completed").unwrap().as_f64(), Some(750.0));
    assert_eq!(r.get("stranded").unwrap().as_f64(), Some(0.0));

    let r = request(
        &addr,
        r#"{"op":"campaign","budget":160,"noise":{"mean_lifetime":3000},"seed":1,"max_rounds":6}"#,
    )
    .unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));

    let r = request(&addr, r#"{"op":"estimate_perf","per_cell":5}"#).unwrap();
    assert!(r.get("max_rel_error").unwrap().as_f64().unwrap() < 1e-6);

    c.shutdown();
}

#[test]
fn shutdown_op_stops_listener() {
    let c = start(false);
    let addr = c.local_addr;
    let r = request(&addr, r#"{"op":"shutdown"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    c.wait(); // must return because the accept loop observed the stop flag

    // New connections must now fail (allow a beat for the socket to close).
    std::thread::sleep(Duration::from_millis(50));
    assert!(request(&addr, r#"{"op":"ping"}"#).is_err());
}

#[test]
fn policy_surface_over_socket() {
    let c = start(false);
    let addr = c.local_addr;

    // Discovery: every registered policy is listed with its name.
    let r = request(&addr, r#"{"op":"list_policies"}"#).unwrap();
    let names: Vec<String> = r
        .get("policies")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|p| p.get("name").unwrap().as_str().unwrap().to_string())
        .collect();
    assert_eq!(names, botsched::scheduler::BUILTIN_POLICIES);

    // A named policy is honoured end-to-end.
    let r = request(&addr, r#"{"op":"plan","budget":80,"policy":"mp"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(r.get("policy").unwrap().as_str(), Some("mp"));

    // A bad policy name surfaces the op and policy in the error.
    let r = request(&addr, r#"{"op":"plan","budget":80,"policy":"bogus"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    let err = r.get("error").unwrap().as_str().unwrap();
    assert!(err.contains("plan") && err.contains("bogus"), "{err}");

    c.shutdown();
}

#[test]
fn sweep_over_socket_matches_library() {
    let c = start(false);
    let addr = c.local_addr;
    let r = request(&addr, r#"{"op":"sweep","budgets":[60,80]}"#).unwrap();
    let rows = r.path(&["sweep", "rows"]).unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 6);

    // Compare with the in-process sweep.
    let sys = botsched::workload::paper::table1_system(0.0);
    let local =
        botsched::analysis::run_sweep(&sys, &[60.0, 80.0], &botsched::eval::NativeEvaluator);
    for row in rows {
        let approach = row.get("approach").unwrap().as_str().unwrap();
        let budget = row.get("budget").unwrap().as_f64().unwrap();
        let makespan = row.get("makespan").unwrap().as_f64().unwrap();
        let want = local.row(approach, budget).unwrap();
        assert!(
            (makespan - want.score.makespan).abs() / want.score.makespan < 1e-3,
            "{approach}@{budget}: {makespan} vs {}",
            want.score.makespan
        );
    }
    c.shutdown();
}
