//! Integration tests for the sharded [`JobEngine`]: FIFO order per
//! shard, bounded concurrency under saturation, concurrent
//! submit/cancel/status races, mid-campaign cancellation and streaming
//! partial results over the protocol, and the deadline policy's
//! speculative parallel probes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use botsched::cloudsim::{run_campaign_replications_ctl, CampaignSpec, NoiseModel};
use botsched::coordinator::api::{
    CampaignRequest, CancelRequest, NoiseSpec, Placement, Request, StatusRequest, SubmitRequest,
    SweepRequest,
};
use botsched::coordinator::protocol::{handle, Context};
use botsched::coordinator::{Busy, JobEngine, JobPriority, JobState, Metrics};
use botsched::eval::NativeEvaluator;
use botsched::scheduler::{PolicyRegistry, SolveRequest};
use botsched::util::{CancelToken, Json};
use botsched::workload::paper::table1_system;

fn engine(shards: usize) -> JobEngine {
    JobEngine::new(shards, Arc::new(Metrics::new()))
}

fn ctx() -> Context {
    Context::new(Arc::new(NativeEvaluator), Arc::new(Metrics::new()))
}

/// Encode a typed request as one protocol line (nothing in this file
/// hand-assembles op JSON strings).
fn line_of(req: &Request) -> String {
    req.encode().to_string()
}

/// Submit a typed request as an async engine job; returns the job id.
fn submit(c: &Context, job: &Request) -> String {
    let req = Request::Submit(SubmitRequest::from_request(job, Placement::default()));
    let r = handle(c, &line_of(&req)).expect("submit");
    r.body.get("job_id").unwrap().as_str().unwrap().to_string()
}

/// Fire a job's cancel token over the protocol; returns the ack flag.
fn cancel(c: &Context, id: &str) -> bool {
    let req = Request::Cancel(CancelRequest { job_id: id.to_string() });
    let r = handle(c, &line_of(&req)).expect("cancel");
    r.body.get("cancelled").unwrap().as_bool().unwrap()
}

/// Poll `status` until `pred` holds or the job goes terminal; returns
/// the last status body.  Panics after ~30s.
fn poll_status(c: &Context, id: &str, pred: impl Fn(&Json) -> bool) -> Json {
    let line = line_of(&Request::Status(StatusRequest {
        job_id: id.to_string(),
        partials_from: None,
    }));
    for _ in 0..30_000 {
        let s = handle(c, &line).expect("status").body;
        let job = s.get("job").expect("job object").clone();
        let state = job.get("state").unwrap().as_str().unwrap().to_string();
        if pred(&job) || state == "done" || state == "failed" || state == "cancelled" {
            return job;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    panic!("status condition never reached for {id}");
}

// ---------------------------------------------------------------------------
// Engine-level behaviour.

#[test]
fn single_shard_keeps_fifo_order_under_saturation() {
    // One shard = one worker: 32 queued jobs must *run* in submission
    // order even though all 32 are queued long before the first
    // completes.
    let e = engine(1);
    let order = Arc::new(Mutex::new(Vec::new()));
    let mut ids = Vec::new();
    for i in 0..32usize {
        let order = Arc::clone(&order);
        ids.push(e.submit(
            "t",
            Box::new(move |_| {
                order.lock().unwrap().push(i);
                Ok(Json::num(i as f64))
            }),
        ));
    }
    for id in &ids {
        let state = e.registry().wait_terminal(id, Duration::from_secs(30)).unwrap();
        assert_eq!(state, JobState::Done);
    }
    let order = order.lock().unwrap();
    assert_eq!(*order, (0..32).collect::<Vec<_>>(), "per-shard FIFO violated");
}

#[test]
fn saturation_never_exceeds_the_worker_count() {
    let shards = 3;
    let e = engine(shards);
    let running = Arc::new(AtomicUsize::new(0));
    let peak = Arc::new(AtomicUsize::new(0));
    let mut ids = Vec::new();
    for _ in 0..24 {
        let running = Arc::clone(&running);
        let peak = Arc::clone(&peak);
        ids.push(e.submit(
            "t",
            Box::new(move |_| {
                let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                peak.fetch_max(now, Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(2));
                running.fetch_sub(1, Ordering::SeqCst);
                Ok(Json::Null)
            }),
        ));
    }
    for id in &ids {
        assert_eq!(
            e.registry().wait_terminal(id, Duration::from_secs(30)),
            Some(JobState::Done)
        );
    }
    let peak = peak.load(Ordering::SeqCst);
    assert!(peak <= shards, "peak concurrency {peak} exceeded {shards} workers");
    assert!(peak >= 1);
}

#[test]
fn work_stealing_drains_a_hot_shard() {
    // 2 workers; all jobs sleep.  Even if every job hashes onto one
    // shard, stealing keeps both workers busy, so 16 x 5ms of work
    // must finish in well under the sequential 80ms x safety margin.
    let e = engine(2);
    let mut ids = Vec::new();
    for _ in 0..16 {
        ids.push(e.submit(
            "t",
            Box::new(|_| {
                std::thread::sleep(Duration::from_millis(5));
                Ok(Json::Null)
            }),
        ));
    }
    for id in &ids {
        assert_eq!(
            e.registry().wait_terminal(id, Duration::from_secs(30)),
            Some(JobState::Done)
        );
    }
    // No timing assertion (CI machines vary); the real check is that
    // both shard queues drained — queue depths are zero.
    assert!(e.queue_depths().iter().all(|&d| d == 0));
}

#[test]
fn concurrent_submit_cancel_status_races_stay_consistent() {
    let e = Arc::new(engine(4));
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let e = Arc::clone(&e);
        handles.push(std::thread::spawn(move || {
            let mut ids = Vec::new();
            for i in 0..25u64 {
                let id = e.submit(
                    "race",
                    Box::new(move |ctl| {
                        // Mixed workload: some spin until cancelled or a
                        // short deadline, some return immediately.
                        if i % 3 == 0 {
                            for _ in 0..50 {
                                if ctl.is_cancelled() {
                                    return Err("cancelled mid-run".into());
                                }
                                std::thread::sleep(Duration::from_micros(200));
                            }
                        }
                        Ok(Json::num(i as f64))
                    }),
                );
                // Hammer status + cancel from the submitting thread.
                let _ = e.registry().status(&id);
                if (i + t) % 2 == 0 {
                    e.registry().cancel(&id);
                }
                let _ = e.registry().status(&id);
                ids.push(id);
            }
            ids
        }));
    }
    let all_ids: Vec<String> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
    assert_eq!(all_ids.len(), 200);
    for id in &all_ids {
        let state = e
            .registry()
            .wait_terminal(id, Duration::from_secs(30))
            .unwrap_or_else(|| panic!("{id} vanished"));
        assert!(state.is_terminal(), "{id} stuck in {:?}", state.as_str());
    }
    // Every id is listed exactly once.
    let list = e.registry().list();
    assert_eq!(list.as_arr().unwrap().len(), 200);
}

#[test]
fn priority_and_deadline_govern_start_order_and_saturation_rejects() {
    // One shard, bounded at 8: everything below runs on one worker, so
    // the observed execution order is exactly the queue's pop order.
    let e = JobEngine::with_backlog(1, 8, Arc::new(Metrics::new()));
    let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
    let (go_tx, go_rx) = std::sync::mpsc::channel::<()>();
    let blocker = e
        .try_submit(
            "block",
            JobPriority::default(),
            Box::new(move |_| {
                started_tx.send(()).unwrap();
                go_rx.recv().unwrap();
                Ok(Json::Null)
            }),
        )
        .unwrap();
    started_rx.recv_timeout(Duration::from_secs(5)).unwrap();

    let order = Arc::new(Mutex::new(Vec::<&'static str>::new()));
    let tag = |name: &'static str| -> botsched::coordinator::engine::JobFn {
        let order = Arc::clone(&order);
        Box::new(move |_| {
            order.lock().unwrap().push(name);
            Ok(Json::Null)
        })
    };
    let mut ids = Vec::new();
    // Submission order deliberately scrambles the intended run order.
    ids.push(e.try_submit("t", JobPriority::default(), tag("p0-first")).unwrap());
    ids.push(e.try_submit("t", JobPriority::default(), tag("p0-second")).unwrap());
    let p5_late = JobPriority::new(5).with_deadline_ms(600_000);
    ids.push(e.try_submit("t", p5_late, tag("p5-late")).unwrap());
    let p5_soon = JobPriority::new(5).with_deadline_ms(1_000);
    ids.push(e.try_submit("t", p5_soon, tag("p5-soon")).unwrap());
    ids.push(e.try_submit("t", JobPriority::new(5), tag("p5-nodeadline")).unwrap());
    ids.push(e.try_submit("t", JobPriority::new(9), tag("p9")).unwrap());
    ids.push(e.try_submit("t", JobPriority::default(), tag("p0-third")).unwrap());
    ids.push(e.try_submit("t", JobPriority::default(), tag("p0-fourth")).unwrap());
    // The queue is now at its bound of 8: the next submit is rejected —
    // admission control is checked before priority, so even a 9 bounces.
    let busy = e
        .try_submit("t", JobPriority::new(9), Box::new(|_| Ok(Json::Null)))
        .unwrap_err();
    assert_eq!(busy, Busy { shard: 0, backlog: 8 });

    go_tx.send(()).unwrap();
    for id in ids.iter().chain(std::iter::once(&blocker)) {
        assert_eq!(
            e.registry().wait_terminal(id, Duration::from_secs(30)),
            Some(JobState::Done),
            "{id}"
        );
    }
    let order = order.lock().unwrap();
    assert_eq!(
        *order,
        [
            "p9",            // highest priority overtakes the whole queue
            "p5-soon",       // earliest deadline wins within the band
            "p5-late",
            "p5-nodeadline", // deadline-less jobs run after EDF peers
            "p0-first",      // the default band keeps plain FIFO
            "p0-second",
            "p0-third",
            "p0-fourth",
        ],
        "queue pop order must be (priority, deadline, FIFO)"
    );
}

#[test]
fn default_priority_jobs_keep_exact_fifo_and_record_queue_wait() {
    // No priority/deadline fields anywhere: the bounded priority queue
    // must degenerate to the old FIFO behaviour bit-for-bit.
    let e = JobEngine::with_backlog(1, 64, Arc::new(Metrics::new()));
    let order = Arc::new(Mutex::new(Vec::new()));
    let mut ids = Vec::new();
    for i in 0..16usize {
        let order = Arc::clone(&order);
        ids.push(e.submit(
            "t",
            Box::new(move |_| {
                order.lock().unwrap().push(i);
                std::thread::sleep(Duration::from_millis(1));
                Ok(Json::Null)
            }),
        ));
    }
    for id in &ids {
        assert_eq!(
            e.registry().wait_terminal(id, Duration::from_secs(30)),
            Some(JobState::Done)
        );
        // Every executed job carries its recorded time-in-queue.
        let status = e.registry().status(id).unwrap();
        assert!(status.get("queue_wait_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert!(status.get("priority").is_none(), "default placement stays implicit");
    }
    assert_eq!(*order.lock().unwrap(), (0..16).collect::<Vec<_>>());
}

// ---------------------------------------------------------------------------
// Cancellation at replication boundaries (deterministic, library level).

#[test]
fn campaign_cancel_stops_within_one_replication_boundary() {
    let sys = table1_system(0.0);
    let mut spec = CampaignSpec::new(200.0);
    spec.sim.noise = NoiseModel::with_failures(0.05, 2500.0);
    spec.sim.seed = 3;
    let cancel = CancelToken::new();
    let completed = AtomicUsize::new(0);
    // Sequential fan-out; the observer cancels after the 3rd finished
    // replication, so replications 4..16 must never start.
    let outs = run_campaign_replications_ctl(&sys, &spec, 16, 1, &cancel, &{
        let cancel = cancel.clone();
        let completed = &completed;
        move |_r, _out| {
            if completed.fetch_add(1, Ordering::SeqCst) + 1 == 3 {
                cancel.cancel();
            }
        }
    });
    assert_eq!(outs.len(), 16, "slot per requested replication");
    let ran = outs.iter().filter(|o| o.is_some()).count();
    assert_eq!(ran, 3, "cancel must stop the fan-out at the replication boundary");
    assert!(outs[3..].iter().all(Option::is_none));
}

// ---------------------------------------------------------------------------
// Protocol-level: jobs on the engine with progress, partials, cancel.

#[test]
fn submitted_campaign_job_reports_progress_and_cancels_mid_flight() {
    let c = ctx();
    // Big Monte-Carlo campaign: hundreds of replications, sequential.
    let id = submit(
        &c,
        &Request::Campaign(
            CampaignRequest::new(150.0)
                .with_replications(2000)
                .with_noise(NoiseSpec { mean_lifetime: Some(2500.0), ..NoiseSpec::default() })
                .with_seed(3)
                .with_max_rounds(6),
        ),
    );

    // Wait until at least two replications finished (progress + partials
    // visible while running), then cancel.
    let job = poll_status(&c, &id, |j| {
        j.path(&["progress", "done"]).and_then(Json::as_f64).unwrap_or(0.0) >= 2.0
    });
    assert_eq!(
        job.get("state").unwrap().as_str(),
        Some("running"),
        "2000 replications cannot finish before the poller sees progress: {job}"
    );
    assert!(job.get("partial_results").is_some(), "partials must stream mid-flight");

    assert!(cancel(&c, &id));
    let state = c.jobs().wait_terminal(&id, Duration::from_secs(60)).unwrap();
    assert_eq!(state, JobState::Cancelled);

    // The job stopped far short of the requested 2000 replications.
    let job = c.jobs().status(&id).unwrap();
    let done = job.path(&["progress", "done"]).unwrap().as_f64().unwrap();
    assert!(done < 2000.0, "cancel did not stop the fan-out (done={done})");
    let partials = job.get("partial_results").unwrap().as_arr().unwrap();
    assert!(!partials.is_empty());
    assert!(partials[0].get("wall_clock").is_some());
}

#[test]
fn sweep_status_streams_progress_and_partial_cells() {
    let c = ctx();
    // 30 budgets x 3 policies = 90 cells, sequential: plenty of window
    // to observe an unfinished sweep.
    let budgets: Vec<f64> = (0..30).map(|i| f64::from(40 + i * 5)).collect();
    let id = submit(
        &c,
        &Request::Sweep(SweepRequest::default().with_budgets(budgets).with_threads(1)),
    );

    // Acceptance: status on an unfinished sweep returns progress counts
    // plus at least one partial cell result.
    let job = poll_status(&c, &id, |j| {
        j.get("partial_results").is_some()
            && j.path(&["progress", "done"]).and_then(Json::as_f64).unwrap_or(0.0) >= 1.0
    });
    assert_eq!(job.get("state").unwrap().as_str(), Some("running"), "{job}");
    let total = job.path(&["progress", "total"]).unwrap().as_f64().unwrap();
    assert_eq!(total, 90.0);
    let cell = &job.get("partial_results").unwrap().as_arr().unwrap()[0];
    assert!(cell.get("policy").is_some());
    assert!(cell.get("makespan").unwrap().as_f64().unwrap() > 0.0);
    assert!(cell.get("budget").is_some());

    // Cancel stops the remaining cells.
    assert!(cancel(&c, &id));
    assert_eq!(
        c.jobs().wait_terminal(&id, Duration::from_secs(60)),
        Some(JobState::Cancelled)
    );
    let done = c
        .jobs()
        .status(&id)
        .unwrap()
        .path(&["progress", "done"])
        .unwrap()
        .as_f64()
        .unwrap();
    assert!(done < 90.0, "cancel did not stop the sweep (done={done})");
}

#[test]
fn synchronous_heavy_ops_flow_through_the_engine() {
    let c = ctx();
    // A sync campaign must produce the usual reply...
    let campaign = Request::Campaign(
        CampaignRequest::new(150.0)
            .with_noise(NoiseSpec { mean_lifetime: Some(2500.0), ..NoiseSpec::default() })
            .with_seed(3)
            .with_max_rounds(6),
    );
    let r = handle(&c, &line_of(&campaign)).unwrap();
    assert_eq!(r.body.get("ok"), Some(&Json::Bool(true)));
    assert!(r.body.get("rounds").unwrap().as_f64().unwrap() >= 1.0);
    // ...and leave a finished job behind in the engine's registry (the
    // proof it ran on the pool, not inline on the connection thread).
    let jobs = handle(&c, &line_of(&Request::Jobs)).unwrap();
    let jobs = jobs.body.get("jobs").unwrap().as_arr().unwrap().to_vec();
    assert!(
        jobs.iter().any(|j| j.get("op").unwrap().as_str() == Some("campaign")
            && j.get("state").unwrap().as_str() == Some("done")),
        "sync campaign missing from the job list: {jobs:?}"
    );
    // stats reports the job counters + engine gauges.
    let s = handle(&c, &line_of(&Request::Stats)).unwrap();
    assert!(s.body.path(&["stats", "jobs_submitted"]).unwrap().as_f64().unwrap() >= 1.0);
    assert!(s.body.path(&["engine", "shards"]).unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(s.body.path(&["engine", "queued"]).unwrap().as_f64(), Some(0.0));
}

#[test]
fn submitted_plan_jobs_still_roundtrip_on_the_pool() {
    // The pre-engine submit/status/cancel surface is preserved.
    let c = ctx();
    let id = submit(
        &c,
        &Request::Plan(botsched::coordinator::api::PlanRequest::new(80.0)),
    );
    assert_eq!(
        c.jobs().wait_terminal(&id, Duration::from_secs(60)),
        Some(JobState::Done)
    );
    let job = c.jobs().status(&id).unwrap();
    assert!(job.path(&["result", "makespan"]).unwrap().as_f64().unwrap() > 0.0);
    // Cancelling a finished job is a no-op.
    assert!(!cancel(&c, &id));
}

// ---------------------------------------------------------------------------
// Deadline policy: parallel probes, identical results.

#[test]
fn deadline_policy_parity_across_thread_counts() {
    let sys = table1_system(0.0);
    let registry = PolicyRegistry::builtin();
    let base = SolveRequest::new(200.0).with_deadline(3600.0);
    let seq = registry.solve("deadline", &sys, &base).unwrap();
    for threads in [2usize, 4, 8] {
        let req = SolveRequest::new(200.0).with_deadline(3600.0).with_threads(threads);
        let par = registry.solve("deadline", &sys, &req).unwrap();
        assert_eq!(par.probes, seq.probes, "threads {threads}");
        assert_eq!(par.effective_budget.to_bits(), seq.effective_budget.to_bits());
        assert_eq!(par.score.makespan.to_bits(), seq.score.makespan.to_bits());
        assert_eq!(par.score.cost.to_bits(), seq.score.cost.to_bits());
        assert_eq!(par.feasible, seq.feasible);
        assert_eq!(par.plan.n_vms(), seq.plan.n_vms());
        for (a, b) in par.plan.vms.iter().zip(&seq.plan.vms) {
            assert_eq!(a.it, b.it, "threads {threads}");
            assert_eq!(a.tasks(), b.tasks(), "threads {threads}");
        }
    }
}
