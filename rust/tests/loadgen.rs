//! Integration tests for the open-loop load generator against a live
//! coordinator: record-and-replay tapes that round-trip byte-identically
//! and replay the exact recorded request sequence, SLO breakdowns under
//! saturation (busy sheds + binding-deadline rejections showing up both
//! client-side and in the server's `stats` delta), the saturation-knee
//! sweep, and the pipelined client's bounded `recv_within` drain.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use botsched::coordinator::api::Request;
use botsched::coordinator::{Client, Coordinator, CoordinatorConfig};
use botsched::loadgen::{
    execute, generate, run_load, run_sweep, ArrivalProcess, DeadlineMix, ExecOptions, LoadConfig,
    MixSpec,
};
use botsched::workload::LoadTrace;

fn start(shards: usize, max_backlog: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        shards,
        conn_workers: 2,
        max_backlog,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts")
}

fn tmp_tape(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("botsched-loadgen-{}-{name}.json", std::process::id()))
}

fn plan_cfg(rate: f64, duration_s: f64, seed: u64) -> LoadConfig {
    LoadConfig {
        rate,
        duration_s,
        clients: 3,
        arrival: ArrivalProcess::Poisson,
        mix: MixSpec::plan_only("uniform-small").expect("builtin scenario"),
        seed,
    }
}

/// Outcomes must partition the sends: nothing double-counted, nothing
/// dropped on the floor.
fn assert_consistent(report: &botsched::loadgen::SloReport) {
    assert_eq!(
        report.served + report.busy + report.deadline_exceeded + report.errors,
        report.sent,
        "outcome breakdown must partition sent ({report:?})"
    );
}

#[test]
fn replay_equals_record_against_a_live_coordinator() {
    let coord = start(2, 0);
    let cfg = plan_cfg(60.0, 0.5, 5);
    let opts = ExecOptions::default();

    let (tape, report) = run_load(&coord.local_addr, &cfg, &opts).expect("recorded run");
    assert_eq!(report.sent, tape.entries.len() as u64, "open loop sends the whole tape");
    assert!(report.sent > 0, "a 60/s half-second run must send something");
    assert_consistent(&report);

    // The tape is a pure function of the config…
    let again = generate(&cfg).expect("regenerate");
    assert_eq!(again, tape);
    assert_eq!(again.to_json().to_string(), tape.to_json().to_string(), "byte-identical tapes");

    // …and survives disk byte-identically through the strict schema.
    let path = tmp_tape("replay");
    tape.save(&path).expect("save tape");
    let loaded = LoadTrace::load(&path).expect("load tape");
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded, tape, "record→save→load is lossless");

    // Replaying the loaded tape drives the identical request sequence.
    let replayed = execute(&coord.local_addr, &loaded, &opts).expect("replayed run");
    assert_eq!(replayed.sent, report.sent);
    assert_consistent(&replayed);
    // The server answered a healthy plan-only tape both times.
    assert!(report.served > 0 && replayed.served > 0, "plan-only traffic should be served");

    coord.shutdown();
}

#[test]
fn saturation_surfaces_busy_and_deadline_breakdowns() {
    // One shard with a backlog bound of 1: an all-campaign burst must
    // shed `busy`, and every admitted campaign carries a 1–2ms binding
    // deadline it cannot meet once anything is queued ahead of it.
    let coord = start(1, 1);
    let mut cfg = plan_cfg(150.0, 0.4, 11);
    cfg.clients = 4;
    cfg.mix = MixSpec::new("uniform-small").expect("builtin scenario");
    cfg.mix.engine_frac = 1.0;
    cfg.mix.deadline = Some(DeadlineMix { prob: 1.0, lo_ms: 1, hi_ms: 2 });
    cfg.mix.validate().expect("saturation mix is valid");

    let (tape, report) = run_load(&coord.local_addr, &cfg, &ExecOptions::default())
        .expect("saturation run");
    assert!(tape.entries.len() > 20, "need a real burst, got {}", tape.entries.len());
    assert_consistent(&report);
    assert!(report.busy >= 1, "backlog bound 1 must shed busy ({report:?})");
    assert!(
        report.deadline_exceeded >= 1,
        "1–2ms binding deadlines must be exceeded under queueing ({report:?})"
    );

    // The server's own counters tell the same story.
    let server = report.server.expect("stats reconciliation delta");
    assert!(server.jobs_rejected >= 1, "server must count the busy sheds ({server:?})");
    assert!(
        server.jobs_deadline_exceeded >= 1,
        "server must count the deadline sheds ({server:?})"
    );

    coord.shutdown();
}

#[test]
fn sweep_reports_points_and_a_knee_field() {
    let coord = start(2, 0);
    let cfg = plan_cfg(25.0, 0.25, 21);
    let sweep = run_sweep(&coord.local_addr, &cfg, &[25.0, 50.0], &ExecOptions::default())
        .expect("sweep");
    assert!(!sweep.points.is_empty() && sweep.points.len() <= 2);
    for p in &sweep.points {
        assert_consistent(p);
    }
    let j = sweep.to_json();
    assert_eq!(
        j.get("points").and_then(|p| p.as_arr()).map(|a| a.len()),
        Some(sweep.points.len())
    );
    assert!(j.get("knee_rate").is_some(), "sweep json carries the knee");
    assert!(sweep.table().contains("offered/s"), "sweep table renders");
    coord.shutdown();
}

#[test]
fn recv_within_drains_pipelined_replies_without_blocking() {
    let coord = start(2, 0);
    let mut client = Client::connect(&coord.local_addr).expect("connect");

    // Nothing pending: an immediate, non-blocking None.
    let t0 = Instant::now();
    assert!(matches!(client.recv_within(Duration::from_secs(5)), Ok(None)));
    assert!(t0.elapsed() < Duration::from_secs(1), "empty drain must not wait");

    for _ in 0..3 {
        client.send(&Request::Ping).expect("pipelined send");
    }
    let mut got = 0;
    let deadline = Instant::now() + Duration::from_secs(5);
    while got < 3 && Instant::now() < deadline {
        match client.recv_within(Duration::from_millis(50)) {
            Ok(Some(_)) => got += 1,
            Ok(None) => {}
            Err(e) => panic!("drain failed: {e}"),
        }
    }
    assert_eq!(got, 3, "all pipelined replies drained within the window");
    // And the client is still usable for ordinary calls afterwards.
    client.ping().expect("client survives the drained pipeline");
    coord.shutdown();
}
