//! Failpoint-driven chaos tests for the serving core: a randomized
//! fault schedule with a SIGKILL mid-chaos (no lost terminal result, no
//! duplicate execution), binding deadlines, torn-write fuzzing of the
//! journal at every byte boundary, degraded-mode health reporting with
//! reattach, watchdog respawns, idle-connection eviction, and panic
//! containment in the request executors.
//!
//! The failpoint registry is process-global, so the in-process tests
//! that arm points serialize on one mutex; CI additionally runs this
//! binary with `--test-threads=1` (like `persist`) to keep the
//! process-level tests from racing each other.

use std::io::{BufRead, BufReader};
use std::net::SocketAddr;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use botsched::coordinator::api::Placement;
use botsched::coordinator::server::request as raw_request;
use botsched::coordinator::{
    Client, ClientError, ClientOptions, Coordinator, CoordinatorConfig, JobPriority, RetryPolicy,
};
use botsched::persist::Journal;
use botsched::util::{failpoint, Json};

/// Serializes in-process tests that touch the global failpoint registry
/// (or fixed point names another test could also arm).
static GLOBAL_FP: Mutex<()> = Mutex::new(());

/// A unique scratch path under the OS temp dir, removed up front so a
/// previous run's leftovers never leak into this one.
fn tmp_journal(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("botsched-chaos-{}-{name}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Spawn `botsched serve` on an ephemeral port with extra flags and
/// return (child, addr) once the listening line is printed.
fn spawn_server(extra: &[&str]) -> (Child, SocketAddr) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_botsched"))
        .args(["serve", "--addr", "127.0.0.1:0", "--no-xla"])
        .args(extra)
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning botsched serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).expect("reading the listening line");
    let addr = line
        .strip_prefix("coordinator listening on ")
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("unexpected startup line: {line:?}"))
        .parse()
        .expect("parsing the listening address");
    // Keep draining stdout so the server never blocks on a full pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while let Ok(n) = reader.read_line(&mut sink) {
            if n == 0 {
                break;
            }
            sink.clear();
        }
    });
    (child, addr)
}

/// A client with the standard retry policy: chaos-injected `busy` and
/// transient transport failures retry instead of failing the test.
fn client(addr: &SocketAddr) -> Client {
    let opts = ClientOptions { retry: RetryPolicy::standard(), ..ClientOptions::default() };
    Client::connect_with(addr, &opts).expect("connecting")
}

fn wait_done(client: &mut Client, id: &str) -> Json {
    let status = client
        .wait_job(id, Duration::from_millis(20), Duration::from_secs(60))
        .expect("polling job status");
    assert_eq!(status.state, "done", "job {id} ended as {:?}: {:?}", status.state, status.error);
    status.result.expect("done job carries its result")
}

// ---------------------------------------------------------------------------
// Capstone: randomized fault schedule + SIGKILL under active chaos.

#[test]
fn randomized_chaos_schedule_loses_no_terminal_results_across_sigkill() {
    let journal = tmp_journal("capstone");
    // A probabilistic schedule across three layers: the cache drops
    // half its inserts, workers stall at solve entry, journal appends
    // stall (but stay durable).  The registry RNG is seeded, so the
    // schedule is randomized per hit yet replayable.
    let chaos = "cache.insert=error@0.5;engine.worker=delay(5)@0.5;journal.append=delay(2)@0.3";
    let (mut child, addr) = spawn_server(&[
        "--journal",
        journal.to_str().unwrap(),
        "--cache-capacity",
        "16",
        "--chaos",
        chaos,
    ]);
    let mut c = client(&addr);

    // Every submit is answered (a clean failure would fail the test
    // here), and every job reaches a terminal result under chaos.
    let mut ids = Vec::new();
    for i in 0..8u32 {
        let line = format!(r#"{{"op":"plan","budget":{}}}"#, 50 + i * 7);
        let id = c
            .submit_raw(Json::parse(&line).unwrap(), Placement::default())
            .unwrap_or_else(|e| panic!("{line}: {e}"));
        ids.push(id);
    }
    let done: Vec<(String, String)> = ids
        .iter()
        .map(|id| (id.clone(), wait_done(&mut c, id).to_string()))
        .collect();

    // SIGKILL while chaos is still armed: no shutdown, no flush.
    child.kill().expect("killing the server");
    child.wait().expect("reaping the server");

    // A clean server on the same journal recovers every terminal
    // result byte-identically.
    let (mut child, addr) = spawn_server(&["--journal", journal.to_str().unwrap()]);
    let mut c = client(&addr);
    for (id, bytes) in &done {
        let st = c.status(id, None).expect("recovered status");
        assert_eq!(st.state, "done", "journaled terminal result lost for {id}");
        assert_eq!(
            &st.result.expect("recovered result").to_string(),
            bytes,
            "{id}: recovered result must be byte-identical"
        );
    }
    // ... and recovered them from replay, not by running anything
    // twice: the fresh engine has executed zero jobs.
    let stats = c.stats().expect("stats").stats;
    assert_eq!(
        stats.get("jobs_done").and_then(Json::as_u64),
        Some(0),
        "journaled jobs must not re-execute: {stats}"
    );
    c.shutdown().expect("shutdown");
    let status = child.wait().expect("server exits");
    assert!(status.success(), "server must exit cleanly after chaos: {status:?}");
    let _ = std::fs::remove_file(&journal);
}

// ---------------------------------------------------------------------------
// Binding deadlines.

#[test]
fn deadline_expired_jobs_are_shed_before_execution() {
    // Not a chaos test, but its submits would be poisoned by another
    // test arming `engine.submit` concurrently — serialize.
    let _g = GLOBAL_FP.lock().unwrap_or_else(|p| p.into_inner());
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        shards: 1,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts");
    let addr = coord.local_addr;
    let mut c = Client::connect(&addr).unwrap();

    // Occupy the single shard with a deliberately long campaign.
    let blocker = c
        .submit_raw(
            Json::parse(
                r#"{"op":"campaign","budget":150,"replications":2048,
                    "noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
            )
            .unwrap(),
            Placement::default(),
        )
        .expect("submitting the blocker");

    // An async job whose 1ms queue deadline expires behind the blocker.
    let doomed = c
        .submit_raw(
            Json::parse(r#"{"op":"plan","budget":80}"#).unwrap(),
            Placement { priority: None, deadline_ms: Some(1) },
        )
        .expect("submitting the doomed job");

    // A synchronous v2 op with an expired deadline fails with the typed
    // code without waiting for the blocker — the wait itself is bounded.
    let reply = raw_request(&addr, r#"{"op":"sweep","budgets":[60],"deadline_ms":1,"v":2}"#)
        .expect("sync sweep answered");
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert_eq!(
        reply.path(&["error", "code"]).and_then(Json::as_str),
        Some("deadline_exceeded"),
        "{reply}"
    );

    // Unblock the shard; the doomed job is shed at pop, never executed.
    c.cancel(&blocker).expect("cancelling the blocker");
    let st = c
        .wait_job(&doomed, Duration::from_millis(20), Duration::from_secs(60))
        .expect("polling the doomed job");
    assert_eq!(st.state, "failed", "{st:?}");
    assert!(
        st.error.as_deref().unwrap_or("").contains("deadline_exceeded"),
        "shed jobs must report deadline_exceeded: {:?}",
        st.error
    );

    // Requests without a deadline are untouched.
    let fine = c
        .submit_raw(Json::parse(r#"{"op":"plan","budget":60}"#).unwrap(), Placement::default())
        .unwrap();
    wait_done(&mut c, &fine);
    c.shutdown().unwrap();
    coord.wait();
}

// ---------------------------------------------------------------------------
// Torn-write fuzz: every byte boundary of a journal frame.

#[test]
fn torn_journal_writes_recover_the_longest_intact_prefix() {
    let _g = GLOBAL_FP.lock().unwrap_or_else(|p| p.into_inner());
    let line = r#"{"op":"ping"}"#;

    // Measure the reference record's full frame length off one clean
    // append, so the fuzz below covers every byte boundary exactly.
    let probe = tmp_journal("torn-probe");
    let (j, _) = Journal::open(&probe).unwrap();
    let before = std::fs::metadata(&probe).unwrap().len();
    j.admit("torn", "ping", line, JobPriority::default());
    let frame_len = (std::fs::metadata(&probe).unwrap().len() - before) as usize;
    drop(j);
    let _ = std::fs::remove_file(&probe);
    assert!(frame_len > 12, "suspicious frame length {frame_len}");

    for cut in 0..frame_len {
        let path = tmp_journal("torn");
        let (j, recovered) = Journal::open(&path).unwrap();
        assert!(recovered.is_empty());
        j.admit("keep", "ping", line, JobPriority::default());
        failpoint::arm(&format!("journal.append=torn_write({cut})x1")).unwrap();
        j.admit("torn", "ping", line, JobPriority::default());
        failpoint::disarm(Some("journal.append"));
        assert!(j.is_degraded(), "cut {cut}: a torn append must degrade the journal");
        drop(j);

        // Replay recovers exactly the records before the tear...
        let (j, recovered) = Journal::open(&path).unwrap();
        let ids: Vec<&str> = recovered.iter().map(|r| r.id.as_str()).collect();
        assert_eq!(ids, ["keep"], "cut {cut}: longest intact prefix");
        // ...and truncated the tear away, so appends are clean again.
        j.admit("after", "ping", line, JobPriority::default());
        assert!(!j.is_degraded(), "cut {cut}: fresh journal must be healthy");
        drop(j);
        let (_j, recovered) = Journal::open(&path).unwrap();
        let ids: Vec<&str> = recovered.iter().map(|r| r.id.as_str()).collect();
        assert_eq!(ids, ["keep", "after"], "cut {cut}: post-recovery append");
        let _ = std::fs::remove_file(&path);
    }
}

// ---------------------------------------------------------------------------
// Graceful degradation: health reporting + journal reattach.

#[test]
fn health_degrades_on_journal_failure_and_reattaches() {
    let journal = tmp_journal("degraded");
    // Exactly two fsync failures: the first admit degrades the journal,
    // the first reattach probe fails, the second probe succeeds.
    let (mut child, addr) = spawn_server(&[
        "--journal",
        journal.to_str().unwrap(),
        "--chaos",
        "journal.fsync=errorx2",
    ]);
    let mut c = client(&addr);

    let h = c.health().expect("health");
    assert!(h.is_ok(), "{h:?}");
    assert_eq!(h.journal_attached, Some(true));

    // The admit's fsync fails: degraded mode, but the job still runs.
    let id = c
        .submit_raw(Json::parse(r#"{"op":"plan","budget":55}"#).unwrap(), Placement::default())
        .expect("submit during fault");
    let h = c.health().expect("health while degraded");
    assert_eq!(h.status, "degraded", "{h:?}");
    assert_eq!(h.journal_attached, Some(false));
    let stats = c.stats().unwrap().stats;
    assert_eq!(stats.get("journal_degraded"), Some(&Json::Bool(true)), "{stats}");
    wait_done(&mut c, &id);

    // The background prober reattaches once the fault budget is spent.
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let h = c.health().expect("health while reattaching");
        if h.is_ok() {
            assert_eq!(h.journal_attached, Some(true));
            break;
        }
        assert!(Instant::now() < deadline, "journal never reattached: {h:?}");
        std::thread::sleep(Duration::from_millis(200));
    }
    let stats = c.stats().unwrap().stats;
    assert!(
        stats.get("journal_reattaches").and_then(Json::as_u64).unwrap_or(0) >= 1,
        "{stats}"
    );
    assert_eq!(stats.get("journal_degraded"), Some(&Json::Bool(false)), "{stats}");

    c.shutdown().unwrap();
    let status = child.wait().expect("server exits");
    assert!(status.success(), "{status:?}");
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn watchdog_respawns_a_stuck_worker() {
    // One 3s stall at solve entry against a 200ms stuck bound.
    let (mut child, addr) = spawn_server(&[
        "--shards",
        "2",
        "--watchdog-stuck-ms",
        "200",
        "--chaos",
        "engine.worker=delay(3000)x1",
    ]);
    let mut c = client(&addr);
    let stuck = c
        .submit_raw(Json::parse(r#"{"op":"plan","budget":77}"#).unwrap(), Placement::default())
        .expect("submitting the stuck job");

    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        let stats = c.stats().unwrap().stats;
        if stats.get("watchdog_respawns").and_then(Json::as_u64).unwrap_or(0) >= 1 {
            break;
        }
        assert!(Instant::now() < deadline, "watchdog never fired: {stats}");
        std::thread::sleep(Duration::from_millis(100));
    }
    // The engine keeps serving on the replacement worker...
    let fresh = c
        .submit_raw(Json::parse(r#"{"op":"plan","budget":88}"#).unwrap(), Placement::default())
        .unwrap();
    wait_done(&mut c, &fresh);
    // ...and the condemned job still reaches a terminal state.
    let st = c
        .wait_job(&stuck, Duration::from_millis(50), Duration::from_secs(30))
        .expect("polling the condemned job");
    assert!(st.is_terminal(), "condemned job stuck in {:?}", st.state);

    c.shutdown().unwrap();
    let status = child.wait().expect("server exits");
    assert!(status.success(), "{status:?}");
}

// ---------------------------------------------------------------------------
// Connection hygiene + executor panic containment.

#[test]
fn idle_connections_are_evicted_after_the_timeout() {
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        conn_idle_timeout: Some(Duration::from_millis(300)),
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts");
    let addr = coord.local_addr;

    // A fail-fast client sees its evicted connection as a transport
    // error...
    let mut fail_fast = Client::connect(&addr).unwrap();
    fail_fast.ping().expect("ping before idling");
    std::thread::sleep(Duration::from_millis(1200));
    let err = fail_fast.ping().expect_err("evicted connection must error");
    assert!(matches!(err, ClientError::Io(_)), "{err}");

    // ...while a retrying client reconnects straight through it.
    let mut retrying = client(&addr);
    retrying.ping().expect("ping before idling");
    std::thread::sleep(Duration::from_millis(1200));
    retrying.ping().expect("the retry policy must reconnect through eviction");
    assert!(retrying.retry_stats().reconnects >= 1);

    Client::connect(&addr).unwrap().shutdown().unwrap();
    coord.wait();
}

#[test]
fn a_panicking_handler_costs_one_reply_not_the_server() {
    let _g = GLOBAL_FP.lock().unwrap_or_else(|p| p.into_inner());
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts");
    let addr = coord.local_addr;

    failpoint::arm("engine.submit=panicx1").unwrap();
    let reply = raw_request(&addr, r#"{"op":"submit","job":{"op":"plan","budget":70}}"#)
        .expect("a panicking handler must still produce a reply");
    failpoint::disarm(Some("engine.submit"));
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert!(reply.to_string().contains("panicked"), "{reply}");

    // The executor pool survives and keeps serving.
    let pong = raw_request(&addr, r#"{"op":"ping"}"#).expect("ping after panic");
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)), "{pong}");
    Client::connect(&addr).unwrap().shutdown().unwrap();
    coord.wait();
}

#[test]
fn the_chaos_op_drives_the_registry_over_the_wire() {
    let _g = GLOBAL_FP.lock().unwrap_or_else(|p| p.into_inner());
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        chaos_allowed: true,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts");
    let addr = coord.local_addr;

    // Arm a probability-0 point (it can never fire) and watch it
    // appear and disappear through the op.
    let armed = raw_request(
        &addr,
        r#"{"op":"chaos","action":"arm","spec":"fp.wire.demo=delay(1)@0x9","v":2}"#,
    )
    .unwrap();
    assert_eq!(armed.get("ok"), Some(&Json::Bool(true)), "{armed}");
    assert!(armed.to_string().contains("fp.wire.demo"), "{armed}");

    let listed = raw_request(&addr, r#"{"op":"chaos","v":2}"#).unwrap();
    assert_eq!(listed.path(&["chaos", "armed"]), Some(&Json::Bool(true)), "{listed}");
    assert!(listed.to_string().contains("delay(1)@0x9"), "{listed}");

    let disarmed =
        raw_request(&addr, r#"{"op":"chaos","action":"disarm","point":"fp.wire.demo","v":2}"#)
            .unwrap();
    assert_eq!(disarmed.get("ok"), Some(&Json::Bool(true)), "{disarmed}");
    assert!(!disarmed.to_string().contains("fp.wire.demo"), "{disarmed}");
    Client::connect(&addr).unwrap().shutdown().unwrap();
    coord.wait();

    // Without --chaos-allowed the op is refused.
    let gated = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let reply = raw_request(&gated.local_addr, r#"{"op":"chaos","v":2}"#).unwrap();
    assert_eq!(reply.get("ok"), Some(&Json::Bool(false)), "{reply}");
    assert!(reply.to_string().contains("--chaos-allowed"), "{reply}");
    Client::connect(&gated.local_addr).unwrap().shutdown().unwrap();
    gated.wait();
}
