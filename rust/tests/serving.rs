//! Integration tests for the admission-controlled serving core: fixed
//! thread pools under hundreds of idle connections, pipelined-request
//! ordering on one socket, interleaved correctness across concurrent
//! sockets, clean shutdown with connections still open, and structured
//! `busy` rejections at the `--max-backlog` bound over a real socket.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use botsched::coordinator::server::request;
use botsched::coordinator::{Coordinator, CoordinatorConfig};
use botsched::util::Json;

fn start(conn_workers: usize, shards: usize, max_backlog: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        shards,
        conn_workers,
        max_backlog,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts")
}

/// A persistent line-protocol client (the `request` helper reconnects
/// per call; these tests need long-lived and pipelined connections).
struct LineClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl LineClient {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .expect("read timeout");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Self { stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).expect("send");
        self.stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("response line");
        assert!(!line.is_empty(), "server closed the connection mid-conversation");
        Json::parse(line.trim()).expect("response json")
    }
}

#[cfg(target_os = "linux")]
fn process_threads() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

#[cfg(target_os = "linux")]
fn threads_named(prefix: &str) -> usize {
    let Ok(dir) = std::fs::read_dir("/proc/self/task") else { return 0 };
    dir.flatten()
        .filter(|e| {
            std::fs::read_to_string(e.path().join("comm"))
                .map(|c| c.trim().starts_with(prefix))
                .unwrap_or(false)
        })
        .count()
}

#[test]
fn hundreds_of_idle_connections_cost_no_threads() {
    #[cfg(target_os = "linux")]
    let baseline = process_threads();

    let c = start(2, 2, 0);
    let addr = c.local_addr;

    // 300 idle spectators: they never send a byte, yet stay connected
    // (each costs the server a poll slot, not a thread).
    let idle: Vec<TcpStream> = (0..300)
        .map(|_| {
            let s = TcpStream::connect(addr).expect("idle connect");
            s.set_read_timeout(Some(Duration::from_secs(60))).ok();
            s
        })
        .collect();

    // Active traffic interleaves correctly across the idle crowd: each
    // client's plan reply echoes the budget it asked for.
    let mut clients: Vec<(f64, LineClient)> = (0..8)
        .map(|i| (60.0 + f64::from(i) * 5.0, LineClient::connect(addr)))
        .collect();
    for (budget, cl) in clients.iter_mut() {
        cl.send(&format!(r#"{{"op":"plan","budget":{budget}}}"#));
    }
    for (budget, cl) in clients.iter_mut() {
        let r = cl.recv();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r}");
        assert_eq!(r.get("budget").unwrap().as_f64(), Some(*budget));
    }
    // The same sockets keep working for a second round (connections are
    // persistent, not request-scoped).
    for (_, cl) in clients.iter_mut() {
        cl.send(r#"{"op":"ping"}"#);
    }
    for (_, cl) in clients.iter_mut() {
        assert_eq!(cl.recv().get("pong"), Some(&Json::Bool(true)));
    }

    // Thread accounting (linux): 300 idle + 8 active connections must
    // not have spawned per-connection threads.  The server adds a fixed
    // set — 1 accept + 2 conn workers + 4 executors + 2 engine shards —
    // and other tests in this binary may run concurrently, so the bound
    // is generous; a thread-per-connection server would add 300+.
    #[cfg(target_os = "linux")]
    {
        let now = process_threads();
        assert!(
            now.saturating_sub(baseline) <= 64,
            "thread count grew with connections: {baseline} -> {now}"
        );
        let conn_workers = threads_named("conn-worker-");
        assert!(
            (2..=16).contains(&conn_workers),
            "expected a small fixed conn-worker pool, found {conn_workers}"
        );
        assert!(threads_named("req-exec-") >= 2, "request executors missing");
    }

    drop(clients);
    drop(idle);
    c.shutdown();
}

#[test]
fn pipelined_requests_on_one_socket_respond_in_order() {
    let c = start(1, 1, 0);
    let addr = c.local_addr;
    let mut cl = LineClient::connect(addr);
    // Three requests in a single write: the server must answer each on
    // its own line, in request order (one in-flight request at a time
    // per connection pins the framing).
    let batch = concat!(
        r#"{"op":"ping"}"#,
        "\n",
        r#"{"op":"plan","budget":60}"#,
        "\n",
        r#"{"op":"plan","budget":80}"#,
        "\n"
    );
    cl.stream.write_all(batch.as_bytes()).unwrap();
    let first = cl.recv();
    assert_eq!(first.get("pong"), Some(&Json::Bool(true)), "{first}");
    let second = cl.recv();
    assert_eq!(second.get("budget").unwrap().as_f64(), Some(60.0));
    let third = cl.recv();
    assert_eq!(third.get("budget").unwrap().as_f64(), Some(80.0));
    // Blank lines are skipped, not answered (parity with the old server).
    cl.stream.write_all(b"\n  \n{\"op\":\"ping\"}\n").unwrap();
    assert_eq!(cl.recv().get("pong"), Some(&Json::Bool(true)));
    // Malformed input still gets an error reply and keeps the socket.
    cl.send("this is not json");
    let r = cl.recv();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    cl.send(r#"{"op":"ping"}"#);
    assert_eq!(cl.recv().get("pong"), Some(&Json::Bool(true)));
    c.shutdown();
}

#[test]
fn shutdown_completes_with_idle_connections_still_open() {
    let c = start(2, 1, 0);
    let addr = c.local_addr;
    let idle: Vec<TcpStream> = (0..50)
        .map(|_| TcpStream::connect(addr).expect("idle connect"))
        .collect();
    // The old thread-per-connection server joined every connection
    // thread on shutdown — with idle clients attached it could never
    // finish.  The readiness-driven server must stop promptly.
    let r = request(&addr, r#"{"op":"shutdown"}"#).unwrap();
    assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
    c.wait(); // returns only after full teardown; a hang here fails CI
    std::thread::sleep(Duration::from_millis(50));
    assert!(request(&addr, r#"{"op":"ping"}"#).is_err(), "listener must be closed");
    drop(idle);
}

#[test]
fn saturating_a_shard_over_the_wire_yields_structured_busy() {
    // One shard, one queue slot: the third concurrent submit must be
    // rejected with the structured busy shape, not hang or queue.
    let c = start(1, 1, 1);
    let addr = c.local_addr;
    let slow = r#"{"op":"submit","job":{"op":"campaign","budget":150,"replications":2000,"noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}}"#;
    let r1 = request(&addr, slow).unwrap();
    let running = r1.get("job_id").unwrap().as_str().unwrap().to_string();
    // Wait until the first job occupies the worker.
    let mut state = String::new();
    for _ in 0..3000 {
        let s = request(&addr, &format!(r#"{{"op":"status","job_id":"{running}"}}"#)).unwrap();
        state = s.path(&["job", "state"]).unwrap().as_str().unwrap().to_string();
        if state == "running" {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(state, "running", "first job never started");
    // Second fills the single queue slot; a high priority cannot talk
    // its way past admission control.
    let r2 = request(&addr, slow).unwrap();
    let queued = r2.get("job_id").unwrap().as_str().unwrap().to_string();
    let r3 = request(
        &addr,
        r#"{"op":"submit","priority":9,"job":{"op":"plan","budget":80}}"#,
    )
    .unwrap();
    assert_eq!(r3.get("ok"), Some(&Json::Bool(false)), "{r3}");
    assert_eq!(r3.get("error").unwrap().as_str(), Some("busy"));
    assert_eq!(r3.get("shard").unwrap().as_f64(), Some(0.0));
    assert_eq!(r3.get("backlog").unwrap().as_f64(), Some(1.0));
    // The rejection shows up in the shard gauges.
    let stats = request(&addr, r#"{"op":"stats"}"#).unwrap();
    let shard0 = &stats.path(&["engine", "shard_stats"]).unwrap().as_arr().unwrap()[0];
    assert!(shard0.get("rejected").unwrap().as_f64().unwrap() >= 1.0);
    assert_eq!(stats.path(&["engine", "max_backlog"]).unwrap().as_f64(), Some(1.0));
    // Clean up: cancel both campaign jobs, then stop the server.
    for id in [&running, &queued] {
        request(&addr, &format!(r#"{{"op":"cancel","job_id":"{id}"}}"#)).unwrap();
    }
    c.shutdown();
}
