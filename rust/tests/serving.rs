//! Integration tests for the admission-controlled serving core: fixed
//! thread pools under hundreds of idle connections, pipelined-request
//! ordering on one socket (driven through the typed client's
//! `send`/`recv`), interleaved correctness across concurrent sockets,
//! clean shutdown with connections still open, and `busy` rejections at
//! the `--max-backlog` bound over a real socket — byte-pinned in the
//! legacy v1 shape via raw lines (the explicit v1-parity fixtures) and
//! typed via the client's `ClientError::Busy`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

use botsched::coordinator::api::{CampaignRequest, NoiseSpec, Placement, PlanRequest, Request};
use botsched::coordinator::server::request;
use botsched::coordinator::{Client, ClientError, Coordinator, CoordinatorConfig};
use botsched::util::Json;

fn start(conn_workers: usize, shards: usize, max_backlog: usize) -> Coordinator {
    Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        shards,
        conn_workers,
        max_backlog,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts")
}

/// A raw line-protocol client for the v1-parity fixtures (byte-exact
/// lines, blank lines, malformed input).  Typed traffic goes through
/// [`Client`].
struct RawClient {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl RawClient {
    fn connect(addr: std::net::SocketAddr) -> Self {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_nodelay(true).ok();
        stream
            .set_read_timeout(Some(Duration::from_secs(60)))
            .expect("read timeout");
        let reader = BufReader::new(stream.try_clone().expect("clone"));
        Self { stream, reader }
    }

    fn send(&mut self, line: &str) {
        self.stream.write_all(line.as_bytes()).expect("send");
        self.stream.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        self.reader.read_line(&mut line).expect("response line");
        assert!(!line.is_empty(), "server closed the connection mid-conversation");
        Json::parse(line.trim()).expect("response json")
    }
}

#[cfg(target_os = "linux")]
fn process_threads() -> usize {
    std::fs::read_dir("/proc/self/task").map(|d| d.count()).unwrap_or(0)
}

#[cfg(target_os = "linux")]
fn threads_named(prefix: &str) -> usize {
    let Ok(dir) = std::fs::read_dir("/proc/self/task") else { return 0 };
    dir.flatten()
        .filter(|e| {
            std::fs::read_to_string(e.path().join("comm"))
                .map(|c| c.trim().starts_with(prefix))
                .unwrap_or(false)
        })
        .count()
}

#[test]
fn hundreds_of_idle_connections_cost_no_threads() {
    #[cfg(target_os = "linux")]
    let baseline = process_threads();

    let c = start(2, 2, 0);
    let addr = c.local_addr;

    // 300 idle spectators: they never send a byte, yet stay connected
    // (each costs the server a poll slot, not a thread).
    let idle: Vec<TcpStream> = (0..300)
        .map(|_| {
            let s = TcpStream::connect(addr).expect("idle connect");
            s.set_read_timeout(Some(Duration::from_secs(60))).ok();
            s
        })
        .collect();

    // Active traffic interleaves correctly across the idle crowd: each
    // typed client's plan reply echoes the budget it asked for.
    let mut clients: Vec<(f64, Client)> = (0..8)
        .map(|i| (60.0 + f64::from(i) * 5.0, Client::connect(&addr).expect("connect")))
        .collect();
    for (budget, cl) in clients.iter_mut() {
        cl.send(&Request::Plan(PlanRequest::new(*budget))).expect("send plan");
    }
    for (budget, cl) in clients.iter_mut() {
        let body = cl.recv().expect("plan reply");
        let plan = botsched::coordinator::api::PlanResponse::decode(&body).expect("typed plan");
        assert_eq!(plan.budget, *budget);
        assert!(plan.makespan > 0.0);
    }
    // The same sockets keep working for a second round (connections are
    // persistent, not request-scoped).
    for (_, cl) in clients.iter_mut() {
        cl.ping().expect("ping");
    }

    // Thread accounting (linux): 300 idle + 8 active connections must
    // not have spawned per-connection threads.  The server adds a fixed
    // set — 1 accept + 2 conn workers + 4 executors + 2 engine shards —
    // and other tests in this binary may run concurrently, so the bound
    // is generous; a thread-per-connection server would add 300+.
    #[cfg(target_os = "linux")]
    {
        let now = process_threads();
        assert!(
            now.saturating_sub(baseline) <= 64,
            "thread count grew with connections: {baseline} -> {now}"
        );
        let conn_workers = threads_named("conn-worker-");
        assert!(
            (2..=16).contains(&conn_workers),
            "expected a small fixed conn-worker pool, found {conn_workers}"
        );
        assert!(threads_named("req-exec-") >= 2, "request executors missing");
    }

    drop(clients);
    drop(idle);
    c.shutdown();
}

#[test]
fn pipelined_requests_on_one_socket_respond_in_order() {
    let c = start(1, 1, 0);
    let addr = c.local_addr;
    // Three requests in flight on one connection through the typed
    // client: the server must answer each on its own line, in request
    // order (one in-flight request at a time per connection pins the
    // framing).
    let mut cl = Client::connect(&addr).unwrap();
    cl.send(&Request::Ping).unwrap();
    cl.send(&Request::Plan(PlanRequest::new(60.0))).unwrap();
    cl.send(&Request::Plan(PlanRequest::new(80.0))).unwrap();
    assert_eq!(cl.pending(), 3);
    let first = cl.recv().unwrap();
    assert_eq!(first.get("pong"), Some(&Json::Bool(true)), "{first}");
    let second = cl.recv().unwrap();
    assert_eq!(second.get("budget").unwrap().as_f64(), Some(60.0));
    let third = cl.recv().unwrap();
    assert_eq!(third.get("budget").unwrap().as_f64(), Some(80.0));
    assert_eq!(cl.pending(), 0);
    // Synchronous calls refuse to run with pipelined replies pending.
    cl.send(&Request::Ping).unwrap();
    assert!(matches!(cl.ping(), Err(ClientError::Protocol(_))));
    cl.recv().unwrap();
    cl.ping().unwrap();

    // v1-parity fixtures (raw bytes): a multi-line burst in a single
    // write, blank lines skipped, malformed input answered with an
    // error while the socket survives.
    let mut raw = RawClient::connect(addr);
    let batch = concat!(
        r#"{"op":"ping"}"#,
        "\n",
        r#"{"op":"plan","budget":60}"#,
        "\n"
    );
    raw.stream.write_all(batch.as_bytes()).unwrap();
    assert_eq!(raw.recv().get("pong"), Some(&Json::Bool(true)));
    assert_eq!(raw.recv().get("budget").unwrap().as_f64(), Some(60.0));
    raw.stream.write_all(b"\n  \n{\"op\":\"ping\"}\n").unwrap();
    assert_eq!(raw.recv().get("pong"), Some(&Json::Bool(true)));
    raw.send("this is not json");
    let r = raw.recv();
    assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
    assert!(r.get("error").unwrap().as_str().is_some(), "v1 errors stay strings: {r}");
    raw.send(r#"{"op":"ping"}"#);
    assert_eq!(raw.recv().get("pong"), Some(&Json::Bool(true)));
    c.shutdown();
}

#[test]
fn shutdown_completes_with_idle_connections_still_open() {
    let c = start(2, 1, 0);
    let addr = c.local_addr;
    let idle: Vec<TcpStream> = (0..50)
        .map(|_| TcpStream::connect(addr).expect("idle connect"))
        .collect();
    // The old thread-per-connection server joined every connection
    // thread on shutdown — with idle clients attached it could never
    // finish.  The readiness-driven server must stop promptly.
    let mut cl = Client::connect(&addr).unwrap();
    cl.shutdown().unwrap();
    c.wait(); // returns only after full teardown; a hang here fails CI
    std::thread::sleep(Duration::from_millis(50));
    assert!(Client::connect(&addr).and_then(|mut c| c.ping()).is_err(), "listener must close");
    drop(idle);
}

#[test]
fn saturating_a_shard_over_the_wire_yields_structured_busy() {
    // One shard, one queue slot: the third concurrent submit must be
    // rejected with the busy shape, not hang or queue.
    let c = start(1, 1, 1);
    let addr = c.local_addr;
    let mut cl = Client::connect(&addr).unwrap();
    let slow_job = Request::Campaign(
        CampaignRequest::new(150.0)
            .with_replications(2000)
            .with_noise(NoiseSpec { mean_lifetime: Some(2500.0), ..NoiseSpec::default() })
            .with_seed(3)
            .with_max_rounds(6),
    );
    let running = cl.submit(&slow_job, Placement::default()).unwrap();
    // Wait until the first job occupies the worker.
    let mut state = String::new();
    for _ in 0..3000 {
        state = cl.status(&running, None).unwrap().state;
        if state == "running" {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(state, "running", "first job never started");
    // Second fills the single queue slot; a high priority cannot talk
    // its way past admission control.
    let queued = cl.submit(&slow_job, Placement::default()).unwrap();
    // v1-parity fixture: the raw version-less reply keeps the exact
    // legacy busy bytes (no retry hint).
    let raw = request(
        &addr,
        r#"{"op":"submit","priority":9,"job":{"op":"plan","budget":80}}"#,
    )
    .unwrap();
    assert_eq!(
        raw.to_string(),
        r#"{"backlog":1,"error":"busy","ok":false,"shard":0}"#
    );
    // The typed client gets the typed rejection, with the queue-wait
    // derived retry hint (the first job started, so the reservoir has
    // at least one sample).
    let placement = Placement { priority: Some(9), deadline_ms: None };
    let err = cl
        .submit(&Request::Plan(PlanRequest::new(80.0)), placement)
        .unwrap_err();
    let ClientError::Busy(busy) = err else { panic!("expected Busy, got {err}") };
    assert_eq!(busy.shard, 0);
    assert_eq!(busy.backlog, 1);
    assert!(busy.retry_after_ms.unwrap() >= 1, "{busy:?}");
    // The rejections show up in the typed shard gauges.
    let stats = cl.stats().unwrap();
    assert_eq!(stats.engine.max_backlog, 1);
    assert!(stats.engine.shard_stats[0].rejected >= 2);
    // Clean up: cancel both campaign jobs, then stop the server.
    for id in [&running, &queued] {
        cl.cancel(id).unwrap();
    }
    c.shutdown();
}
