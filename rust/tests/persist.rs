//! Durability integration tests: crash-recovery through a real SIGKILL
//! of the server binary, clean-restart replay in process, solve-cache
//! end-to-end behaviour over the wire, and cache-key stability.
//!
//! These tests spawn servers bound to ephemeral ports and share journal
//! files on disk; run them single-threaded (`--test-threads=1`, as CI
//! does) to keep the process-level tests from racing each other.

use std::io::{BufRead, BufReader};
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use botsched::coordinator::api::{PlanRequest, Request, SystemRef};
use botsched::coordinator::{Client, Coordinator, CoordinatorConfig};
use botsched::util::Json;

/// A unique scratch path under the OS temp dir, removed up front so a
/// previous run's leftovers never leak into this one.
fn tmp_journal(name: &str) -> PathBuf {
    let p = std::env::temp_dir().join(format!("botsched-persist-{}-{name}", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

/// Spawn `botsched serve` on an ephemeral port with the given journal
/// and return (child, addr) once the listening line is printed.
fn spawn_server(journal: &PathBuf) -> (Child, std::net::SocketAddr) {
    let mut child = Command::new(env!("CARGO_BIN_EXE_botsched"))
        .args([
            "serve",
            "--addr",
            "127.0.0.1:0",
            "--no-xla",
            "--journal",
            journal.to_str().unwrap(),
            "--cache-capacity",
            "16",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning botsched serve");
    let stdout = child.stdout.take().expect("piped stdout");
    let mut reader = BufReader::new(stdout);
    let mut line = String::new();
    reader.read_line(&mut line).expect("reading the listening line");
    let addr = line
        .strip_prefix("coordinator listening on ")
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("unexpected startup line: {line:?}"))
        .parse()
        .expect("parsing the listening address");
    // Keep draining stdout in the background so the server never blocks
    // on a full pipe.
    std::thread::spawn(move || {
        let mut sink = String::new();
        while let Ok(n) = reader.read_line(&mut sink) {
            if n == 0 {
                break;
            }
            sink.clear();
        }
    });
    (child, addr)
}

fn wait_done(client: &mut Client, id: &str) -> Json {
    let status = client
        .wait_job(id, Duration::from_millis(20), Duration::from_secs(60))
        .expect("polling job status");
    assert_eq!(status.state, "done", "job {id} ended as {:?}: {:?}", status.state, status.error);
    status.result.expect("done job carries its result")
}

#[test]
fn sigkill_crash_recovers_results_and_requeues_unfinished_jobs() {
    let journal = tmp_journal("crash");

    // --- First server life: one finished job, one mid-flight job. ---
    let (mut child, addr) = spawn_server(&journal);
    let mut client = Client::connect(&addr).expect("connecting");
    let plan_id = client
        .submit_raw(
            Json::parse(r#"{"op":"plan","budget":80}"#).unwrap(),
            botsched::coordinator::api::Placement::default(),
        )
        .expect("submitting plan job");
    let plan_result = wait_done(&mut client, &plan_id);

    // A deliberately long Monte-Carlo campaign that will still be
    // running when the process dies.
    let campaign_id = client
        .submit_raw(
            Json::parse(
                r#"{"op":"campaign","budget":150,"replications":2048,
                    "noise":{"mean_lifetime":2500},"seed":3,"max_rounds":6}"#,
            )
            .unwrap(),
            botsched::coordinator::api::Placement::default(),
        )
        .expect("submitting campaign job");
    // It is registered (any state) — the accept record is already
    // fsynced, so the kill below cannot lose it.
    let st = client.status(&campaign_id, None).expect("campaign status");
    assert!(!st.state.is_empty());

    // --- Crash: SIGKILL, no shutdown handshake, no flush. ---
    child.kill().expect("killing the server");
    child.wait().expect("reaping the server");

    // --- Second server life: same journal. ---
    let (mut child, addr) = spawn_server(&journal);
    let mut client = Client::connect(&addr).expect("reconnecting");

    // The finished job's result survived byte-identically.
    let recovered = client.status(&plan_id, None).expect("recovered status");
    assert_eq!(recovered.state, "done");
    assert_eq!(
        recovered.result.expect("recovered result").to_string(),
        plan_result.to_string(),
        "recovered result must be byte-identical"
    );

    // The unfinished job re-enqueued under its original id and is
    // running (or already finished) again.
    let st = client.status(&campaign_id, None).expect("requeued status");
    assert!(
        matches!(st.state.as_str(), "queued" | "running" | "done" | "cancelled"),
        "unexpected replayed state {:?}",
        st.state
    );
    // New submissions never collide with recovered ids.
    let fresh = client
        .submit_raw(
            Json::parse(r#"{"op":"plan","budget":60}"#).unwrap(),
            botsched::coordinator::api::Placement::default(),
        )
        .expect("fresh submit");
    assert_ne!(fresh, plan_id);
    assert_ne!(fresh, campaign_id);

    // The persist op reports the journal as live.
    let persist = client.persist(false).expect("persist stats");
    assert_eq!(persist.path(&["journal", "enabled"]), Some(&Json::Bool(true)));
    assert!(persist.path(&["journal", "records"]).unwrap().as_u64().unwrap() >= 3);

    client.cancel(&campaign_id).ok();
    client.shutdown().expect("shutdown");
    child.wait().expect("server exits");
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn clean_restart_replays_in_process() {
    let journal = tmp_journal("clean");
    let config = || CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        journal: Some(journal.clone()),
        cache_capacity: 4,
        ..CoordinatorConfig::default()
    };

    let coord = Coordinator::start(config()).expect("first start");
    let mut client = Client::connect(&coord.local_addr).unwrap();
    let id = client
        .submit_raw(
            Json::parse(r#"{"op":"plan","budget":70,"detail":true}"#).unwrap(),
            botsched::coordinator::api::Placement::default(),
        )
        .unwrap();
    let result = wait_done(&mut client, &id);
    drop(client);
    coord.shutdown();

    let coord = Coordinator::start(config()).expect("restart on the same journal");
    let mut client = Client::connect(&coord.local_addr).unwrap();
    let replayed = client.status(&id, None).expect("replayed job");
    assert_eq!(replayed.state, "done");
    assert_eq!(replayed.result.unwrap().to_string(), result.to_string());
    // Forcing a compaction over the wire keeps the replayed state.
    let persist = client.persist(true).expect("compacting");
    assert!(persist.path(&["journal", "compactions"]).unwrap().as_u64().unwrap() >= 1);
    drop(client);
    coord.shutdown();

    let coord = Coordinator::start(config()).expect("restart after compaction");
    let mut client = Client::connect(&coord.local_addr).unwrap();
    let replayed = client.status(&id, None).expect("job survives compaction");
    assert_eq!(replayed.state, "done");
    assert_eq!(replayed.result.unwrap().to_string(), result.to_string());
    drop(client);
    coord.shutdown();
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn cache_serves_repeated_plans_over_the_wire() {
    let coord = Coordinator::start(CoordinatorConfig {
        addr: "127.0.0.1:0".into(),
        use_xla: false,
        batching: false,
        cache_capacity: 8,
        ..CoordinatorConfig::default()
    })
    .expect("coordinator starts");
    let mut client = Client::connect(&coord.local_addr).unwrap();

    let req = PlanRequest::new(80.0);
    let a = client.plan(&req).unwrap();
    let b = client.plan(&req).unwrap();
    assert_eq!(a, b, "cached plan must match the solved one");

    let stats = client.stats().unwrap().stats;
    assert!(stats.get("cache_hits").unwrap().as_u64().unwrap() >= 1);
    assert!(stats.get("cache_misses").unwrap().as_u64().unwrap() >= 1);
    assert!(stats.get("cache_inserts").unwrap().as_u64().unwrap() >= 1);

    let persist = client.persist(false).unwrap();
    assert_eq!(persist.path(&["cache", "enabled"]), Some(&Json::Bool(true)));
    assert_eq!(persist.path(&["cache", "capacity"]).unwrap().as_u64(), Some(8));
    assert!(persist.path(&["cache", "entries"]).unwrap().as_u64().unwrap() >= 1);
    // No journal configured: enabled=false, and compaction is refused.
    assert_eq!(persist.path(&["journal", "enabled"]), Some(&Json::Bool(false)));
    assert!(client.persist(true).is_err(), "compact without a journal must fail");

    client.shutdown().unwrap();
    coord.wait();
}

#[test]
fn cache_keys_are_stable_across_wire_field_order() {
    let decode_plan = |line: &str| -> PlanRequest {
        match Request::decode(&Json::parse(line).unwrap()).unwrap() {
            Request::Plan(r) => r,
            other => panic!("expected a plan request, got {other:?}"),
        }
    };
    let a = decode_plan(r#"{"op":"plan","budget":80,"policy":"mp","seed":3}"#);
    let b = decode_plan(r#"{"seed":3,"policy":"mp","budget":80,"op":"plan"}"#);
    assert_eq!(a.cache_key(), b.cache_key(), "field order must not fragment the cache");
    // Presentation knobs are excluded; solution-relevant knobs are not.
    let c = decode_plan(r#"{"op":"plan","budget":80,"policy":"mp","seed":3,"threads":4,"detail":true}"#);
    assert_eq!(a.cache_key(), c.cache_key());
    for different in [
        r#"{"op":"plan","budget":81,"policy":"mp","seed":3}"#,
        r#"{"op":"plan","budget":80,"policy":"mi","seed":3}"#,
        r#"{"op":"plan","budget":80,"policy":"mp","seed":4}"#,
        r#"{"op":"plan","budget":80,"policy":"mp","seed":3,"scenario":"heavy-tail"}"#,
    ] {
        assert_ne!(a.cache_key(), decode_plan(different).cache_key(), "{different}");
    }
    // The version stamp is part of every key.
    assert!(a.cache_key().contains("cache_version"));
    // The typed builder and the wire decode agree.
    let typed = PlanRequest::new(80.0).with_policy("mp").with_seed(3);
    assert_eq!(typed.cache_key(), a.cache_key());
    let scoped = PlanRequest::new(500.0).with_target(SystemRef::scenario("heavy-tail"));
    assert_ne!(typed.cache_key(), scoped.cache_key());
}
