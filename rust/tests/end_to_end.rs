//! Whole-stack integration: plan with the (XLA-backed when built)
//! evaluator, execute on the simulated cloud, survive failures via
//! dynamic re-planning, and bootstrap the performance matrix from test
//! runs — the full lifecycle a downstream user runs.

use std::sync::Arc;
use std::time::Duration;

use botsched::cloudsim::{
    run_campaign, sample_runs, CampaignSpec, NoiseModel, SimConfig, Simulator,
};
use botsched::coordinator::{BatchingEvaluator, Metrics};
use botsched::eval::{NativeEvaluator, PlanEvaluator};
use botsched::model::{PerfMatrix, System, SystemBuilder};
use botsched::runtime::XlaEvaluator;
use botsched::scheduler::{deadline, Planner};
use botsched::workload::paper::{table1_system, BUDGETS};

fn evaluator() -> Arc<dyn PlanEvaluator> {
    match XlaEvaluator::load() {
        Ok(x) => Arc::new(x),
        Err(_) => Arc::new(NativeEvaluator),
    }
}

#[test]
fn paper_workload_full_lifecycle() {
    let sys = table1_system(0.0);
    let eval = evaluator();

    // 1. Plan at a feasible budget.
    let report = Planner::with_evaluator(&sys, eval.as_ref()).find(80.0);
    assert!(report.feasible);
    assert!(report.plan.validate_partition(&sys).is_ok());

    // 2. Execute on the clean simulated cloud: prediction must hold.
    let sim = Simulator::run_plan(&sys, &report.plan, &SimConfig::default());
    assert!(sim.all_done());
    assert!((sim.makespan - report.score.makespan).abs() / report.score.makespan < 1e-3);
    assert!((sim.cost - report.score.cost).abs() < 1e-6);

    // 3. Execute on a jittery cloud: everything still completes and the
    //    makespan lands near the prediction.
    let jitter = SimConfig { noise: NoiseModel::jitter(0.08), seed: 4 };
    let sim = Simulator::run_plan(&sys, &report.plan, &jitter);
    assert!(sim.all_done());
    let rel = (sim.makespan - report.score.makespan).abs() / report.score.makespan;
    assert!(rel < 0.30, "jittered makespan off by {rel}");
}

#[test]
fn failing_cloud_campaign_completes_within_relaxed_budget() {
    let sys = table1_system(0.0);
    let mut spec = CampaignSpec::new(220.0).with_reserve(0.5);
    spec.sim.noise = NoiseModel::with_failures(0.05, 2800.0);
    spec.sim.seed = 17;
    let out = run_campaign(&sys, &spec);
    assert!(out.complete, "campaign did not finish");
    assert!(out.within_budget, "spent {} of 220", out.spent);
    let done: usize = out.rounds.iter().map(|r| r.completed.len()).sum();
    assert_eq!(done, 750);
}

#[test]
fn perf_matrix_bootstrap_then_plan_is_sound() {
    // The paper's Sec. III-A pipeline: estimate P from test runs, plan on
    // the estimate, execute on the *true* system.
    let truth = table1_system(0.0);
    let obs = sample_runs(&truth, 25, &NoiseModel::jitter(0.05), 21);
    let prior = vec![15.0; 12];
    let est =
        botsched::cloudsim::sampling::estimate_perf_native(&truth, &obs, &prior, 1e-6);

    // Build the estimated system.
    let mut b = SystemBuilder::new();
    for app in &truth.apps {
        b = b.app(&app.name, app.task_sizes.clone());
    }
    for it in &truth.instance_types {
        let row: Vec<f64> =
            (0..truth.n_apps()).map(|a| est[it.id.index() * truth.n_apps() + a]).collect();
        b = b.instance_type(&it.name, it.cost_per_hour, row);
    }
    let believed: System = b.build().unwrap();
    assert_eq!(believed.perf.n_types(), truth.perf.n_types());

    // Plan on beliefs, execute on truth.
    let report = Planner::new(&believed).find(80.0);
    let sim = Simulator::run_plan(&truth, &report.plan, &SimConfig::default());
    assert!(sim.all_done());
    let rel = (sim.makespan - report.score.makespan).abs() / report.score.makespan;
    assert!(rel < 0.15, "belief/truth divergence {rel}");
}

#[test]
fn deadline_extension_end_to_end() {
    let sys = table1_system(0.0);
    let r = deadline::min_cost_for_deadline(&sys, 2.0 * 3600.0, 160.0);
    let rep = r.report.expect("2h deadline satisfiable under 160");
    let sim = Simulator::run_plan(&sys, &rep.plan, &SimConfig::default());
    assert!(sim.all_done());
    assert!(sim.makespan <= 2.0 * 3600.0 + 1e-6);
}

#[test]
fn batched_xla_planner_sweep_matches_unbatched() {
    let sys = table1_system(0.0);
    let base = evaluator();
    let metrics = Arc::new(Metrics::new());
    let batched = BatchingEvaluator::new(
        Arc::clone(&base),
        64,
        Duration::from_millis(1),
        Arc::clone(&metrics),
    );
    for &b in &BUDGETS[..4] {
        let direct = Planner::with_evaluator(&sys, base.as_ref()).find(b);
        let via_batch = Planner::with_evaluator(&sys, &batched).find(b);
        assert!(
            (direct.score.makespan - via_batch.score.makespan).abs() < 1e-3,
            "budget {b}: {} vs {}",
            direct.score.makespan,
            via_batch.score.makespan
        );
    }
    let snap = metrics.snapshot();
    assert!(snap.get("eval_batches").unwrap().as_f64().unwrap() >= 1.0);
}

#[test]
fn perf_matrix_validation_rejects_garbage() {
    // End-to-end guardrail: a corrupted estimate must be rejected at
    // system construction, not silently planned on.
    let r = std::panic::catch_unwind(|| {
        PerfMatrix::new(1, 1, vec![f64::NAN]);
    });
    assert!(r.is_err());
}
